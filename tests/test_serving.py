"""Serving engine: continuous batching, mode equivalence, SLO accounting."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.serving import (ServingEngine, Tenant, bursty_arrivals, make_trace,
                           poisson_arrivals)
import numpy as np


@pytest.fixture(scope="module")
def tenants_factory():
    models = {}

    def mk(arch, seed):
        if arch not in models:
            cfg = smoke_config(arch)
            m = Model(cfg, param_dtype=jnp.float32)
            models[arch] = (m, m.init(jax.random.PRNGKey(seed)))
        return models[arch]

    def factory():
        m1, p1 = mk("gemma3-1b", 1)
        m2, p2 = mk("mamba2-2.7b", 2)
        return [Tenant("t1", m1, p1, cache_len=32, max_batch=4),
                Tenant("t2", m2, p2, cache_len=32, max_batch=4)]

    return factory


def _trace():
    return make_trace(["t1", "t2"], rate_hz=1e5, n_per_tenant=3,
                      prompt_len=8, max_new_tokens=3, slo_s=1.0)


def test_modes_generate_identical_tokens(tenants_factory):
    outs = {}
    for mode in ("time", "batched", "vliw"):
        eng = ServingEngine(tenants_factory(), mode=mode)
        rep = eng.run(_trace())
        outs[mode] = [r.tokens_out for r in
                      sorted(rep.requests, key=lambda r: r.req_id)]
        assert all(len(t) == 3 for t in outs[mode])
    assert outs["time"] == outs["batched"] == outs["vliw"]


def test_vliw_not_slower_than_time_mode(tenants_factory):
    reps = {}
    for mode in ("time", "vliw"):
        eng = ServingEngine(tenants_factory(), mode=mode)
        reps[mode] = eng.run(_trace())
    assert reps["vliw"].modeled_time_s <= reps["time"].modeled_time_s * 1.001
    assert reps["vliw"].jit.superkernels > 0


def test_continuous_batching_admits_midstream(tenants_factory):
    """A request arriving while others are mid-decode joins the running
    batch (slot insert with its own position)."""
    trace = make_trace(["t1"], rate_hz=1e5, n_per_tenant=2, prompt_len=6,
                       max_new_tokens=6, slo_s=1.0)
    # force the second request to arrive strictly later
    trace[1].arrival_t = trace[0].arrival_t + 1e-9
    eng = ServingEngine(tenants_factory()[:1], mode="batched")
    rep = eng.run(trace)
    assert all(len(r.tokens_out) == 6 for r in rep.requests)
    assert rep.slo_attainment == 1.0


def test_arrival_processes():
    rng = np.random.default_rng(0)
    p = poisson_arrivals(100.0, 50, rng)
    b = bursty_arrivals(100.0, 50, rng)
    assert len(p) == len(b) == 50
    assert all(x < y for x, y in zip(p, p[1:]))
    assert all(x < y for x, y in zip(b, b[1:]))
    # bursty trace has higher inter-arrival variance
    assert np.var(np.diff(b)) != pytest.approx(np.var(np.diff(p)))
