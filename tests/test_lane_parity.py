"""Interpret-vs-compiled Pallas lane parity (PR 9 satellite).

The compiled lane (``REPRO_PALLAS_INTERPRET=0``) is the wall-clock regime
every perf claim is measured in; interpret mode is the correctness regime
CI runs everywhere. These tests pin the contract between them: at pow2
dims — where the tuned pow2 ``bk`` equals K and both lanes reduce in one
k-step — outputs are BIT-identical; when ``bk`` splits K the compiled
MXU may reassociate the partial-sum adds, so parity is within a documented
last-ulp tolerance instead.

Skips wholesale on hosts without a usable compiled lane (CPU jaxlib:
``Only interpret mode is supported on CPU backend``) via the same
``compiled_lane_available()`` probe the benches and CI gate on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as kops
from repro.kernels import coalesced_gemm, coalesced_gemv, flash_attention
from repro.kernels.ops import execute_superkernel, pack_problems

pytestmark = pytest.mark.skipif(
    not kops.compiled_lane_available(),
    reason="no compiled Pallas lane on this host (interpret-only backend)")

# one k-step (bk == K): both lanes reduce identically -> bit parity
EXACT = dict(rtol=0, atol=0)
# bk < K splits the reduction; compiled MXU may reassociate partial sums
SPLIT_TOL = dict(rtol=1e-6, atol=1e-6)


def _problems(rng, g, m, n, k, dtype=jnp.float32):
    ks = jax.random.split(rng, 2 * g)
    return [(jax.random.normal(ks[2 * i], (m, k), dtype),
             jax.random.normal(ks[2 * i + 1], (k, n), dtype))
            for i in range(g)]


@pytest.mark.parametrize("shared", [False, True],
                         ids=["grouped", "shared-operand"])
def test_superkernel_parity_pow2(rng, shared):
    probs = _problems(rng, 3, 16, 256, 256)
    if shared:
        w = probs[0][1]
        probs = [(a, w) for a, _ in probs]
    outs_i = execute_superkernel(probs, bm=16, bn=128, bk=256,
                                 shared_operand=shared, interpret=True)
    outs_c = execute_superkernel(probs, bm=16, bn=128, bk=256,
                                 shared_operand=shared, interpret=False)
    for oi, oc in zip(outs_i, outs_c):
        np.testing.assert_allclose(np.asarray(oi), np.asarray(oc), **EXACT)


def test_coalesced_gemm_parity_bk_split(rng):
    """bk=128 over K=512: four-step reduction, documented tolerance."""
    probs = _problems(rng, 2, 32, 128, 512)
    packed = pack_problems(probs, bm=32)
    args = (packed.a_packed, packed.b_stacked, packed.group_ids)
    oi = coalesced_gemm(*args, bm=32, bn=128, bk=128, interpret=True)
    oc = coalesced_gemm(*args, bm=32, bn=128, bk=128, interpret=False)
    np.testing.assert_allclose(np.asarray(oi), np.asarray(oc), **SPLIT_TOL)


def test_coalesced_gemv_parity(rng):
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (4, 256), jnp.float32)
    w = jax.random.normal(k2, (4, 256, 128), jnp.float32)
    oi = coalesced_gemv(x, w, bn=128, bk=256, interpret=True)
    oc = coalesced_gemv(x, w, bn=128, bk=256, interpret=False)
    np.testing.assert_allclose(np.asarray(oi), np.asarray(oc), **EXACT)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_parity(rng, causal):
    """Both lanes run the SAME online-softmax recurrence over identical
    kv-block ordering, so parity is exact at one kv step and last-ulp
    across splits; we pin the split case at the documented tolerance."""
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (2, 256, 64), jnp.float32)
    k = jax.random.normal(k2, (2, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (2, 256, 64), jnp.float32)
    oi = flash_attention(q, k, v, bq=128, bkv=128, causal=causal,
                         interpret=True)
    oc = flash_attention(q, k, v, bq=128, bkv=128, causal=causal,
                         interpret=False)
    np.testing.assert_allclose(np.asarray(oi), np.asarray(oc), **SPLIT_TOL)


def test_stacked_scan_parity(rng):
    """The layer-stacked regime: scan-over-layers drives the same
    coalesced_gemm body once per layer with a fresh weight slice."""
    L, m, k = 3, 16, 256
    ka, kw = jax.random.split(rng)
    a = jax.random.normal(ka, (m, k), jnp.float32)
    ws = jax.random.normal(kw, (L, 1, k, k), jnp.float32)
    gids = jnp.zeros((m // 16,), jnp.int32)

    def run(interpret):
        def body(x, w):
            return coalesced_gemm(x, w, gids, bm=16, bn=128, bk=k,
                                  interpret=interpret), None
        out, _ = jax.lax.scan(body, a, ws)
        return out

    np.testing.assert_allclose(np.asarray(run(True)), np.asarray(run(False)),
                               **EXACT)
