"""The real-execution OoO VLIW JIT: layerwise programs must bit-match the
monolithic decode, coalescing across tenants, shared-weight detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.jit import VLIWJit, build_dense_decode_program
from repro.models import Model


def _setup(arch, rng, B=2, S=12, CL=32):
    cfg = smoke_config(arch)
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=CL)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (B, 1), 0,
                             cfg.vocab_size)
    return m, params, cache, tok


@pytest.mark.parametrize("arch", ["gemma3-1b", "yi-9b", "granite-34b"])
def test_program_matches_monolithic_decode(arch, rng):
    m, params, cache, tok = _setup(arch, rng)
    want, want_cache = m.decode_step(params, tok, cache)
    prog = build_dense_decode_program(m, params, tok, cache, stream_id=0)
    VLIWJit(max_group=8).run([prog])
    np.testing.assert_allclose(prog.env["logits"][:, None, :], want,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(prog.env["cache"]["layers"]["k"],
                               want_cache["layers"]["k"], rtol=2e-4,
                               atol=2e-4)
    assert int(prog.env["cache"]["pos"][0]) == int(want_cache["pos"][0])


def test_same_model_tenants_share_weights(rng):
    m, params, cache, tok = _setup("gemma3-1b", rng)
    progs = [build_dense_decode_program(m, params, tok, cache, stream_id=i)
             for i in range(3)]
    stats = VLIWJit(max_group=8).run(progs)
    # lockstep same-model streams must coalesce with operand sharing
    assert stats.shared_dispatches == stats.superkernels
    assert stats.mean_group == pytest.approx(3.0)
    assert stats.modeled_speedup > 1.5


def test_cross_model_coalescing(rng):
    """Different models with shape-compatible layers coalesce WITHOUT
    operand sharing (the OoO cross-stream case)."""
    m1, p1, c1, t1 = _setup("gemma3-1b", rng)
    m2, p2, c2, t2 = _setup("yi-9b", jax.random.fold_in(rng, 1))
    prog1 = build_dense_decode_program(m1, p1, t1, c1, stream_id=0)
    prog2 = build_dense_decode_program(m2, p2, t2, c2, stream_id=1)
    stats = VLIWJit(max_group=8).run([prog1, prog2])
    assert stats.mean_group > 1.0          # some cross-model groups formed
    # results still correct per model
    want1, _ = m1.decode_step(p1, t1, c1)
    want2, _ = m2.decode_step(p2, t2, c2)
    np.testing.assert_allclose(prog1.env["logits"][:, None, :], want1,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(prog2.env["logits"][:, None, :], want2,
                               rtol=2e-4, atol=2e-4)
