"""The trip-count-aware HLO analyzer: flops/bytes/collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_parse import analyze_hlo
from repro.launch.hlo_analysis import model_flops_for, roofline
from repro.configs import INPUT_SHAPES, get_config


def test_scan_trip_count_flops():
    def many(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.zeros((128, 128))
    w = jnp.zeros((128, 128))
    c = jax.jit(many).lower(x, w).compile()
    t = analyze_hlo(c.as_text())
    assert t.flops == pytest.approx(10 * 2 * 128 ** 3)
    # XLA's own analysis undercounts by the trip count (the reason this
    # module exists); newer JAX returns a per-device list from cost_analysis
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] == pytest.approx(2 * 128 ** 3, rel=0.01)


def test_nested_scan_flops():
    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jnp.zeros((64, 64))
    w = jnp.zeros((64, 64))
    c = jax.jit(nested).lower(x, w).compile()
    assert analyze_hlo(c.as_text()).flops == pytest.approx(15 * 2 * 64 ** 3)


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)
    a = jnp.zeros((4, 32, 64))
    b = jnp.zeros((4, 64, 16))
    c = jax.jit(f).lower(a, b).compile()
    assert analyze_hlo(c.as_text()).flops == pytest.approx(
        2 * 4 * 32 * 64 * 16)


def test_bytes_positive_and_collectives_zero_on_one_device():
    f = lambda a: (a @ a).sum()
    a = jnp.zeros((64, 64))
    t = analyze_hlo(jax.jit(f).lower(a).compile().as_text())
    assert t.bytes > 0
    assert t.collective_bytes == 0


def test_model_flops_formulas():
    cfg = get_config("yi-9b")
    tr = model_flops_for(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops_for(cfg, INPUT_SHAPES["prefill_32k"])
    de = model_flops_for(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert de == pytest.approx(2 * n * 128)
    # MoE uses active params
    moe = get_config("grok-1-314b")
    assert model_flops_for(moe, INPUT_SHAPES["train_4k"]) \
        < 6 * moe.param_count() * 256 * 4096


def test_roofline_terms_and_dominance():
    t = roofline(hlo_flops=197e12, hlo_bytes=819e9, coll_bytes=0, chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    t2 = roofline(1.0, 1.0, 50e9 * 10, chips=256)
    assert t2.dominant == "collective"
    assert t2.collective_s == pytest.approx(10.0)
