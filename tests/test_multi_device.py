"""Multi-device mesh serving: placement, per-device isolation, token
bit-identity across mesh sizes, device-keyed caches, and the certifier's
placement-hazard taxonomy.

The mesh is MODELED — N virtual device timelines over one host — so token
streams must be bit-identical at every mesh size: placement changes time
attribution, never a tenant's execution math or step order.
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.certify import certify_trace, check_conservation
from repro.configs import smoke_config
from repro.core import GemmShape, make_op
from repro.core.coalescer import Coalescer
from repro.core.costmodel import CostModel, TPUV5E, V100
from repro.core.dispatch import SuperkernelExecutor
from repro.core.plancache import PlanCache
from repro.core.schedtrace import PlacementHazard
from repro.distributed import DeviceSet, PlacementPolicy
from repro.models import Model
from repro.serving import ServingEngine, Tenant, make_trace
import numpy as np


# ---------------------------------------------------------------------------
# fleet fixture: 8 tenants, mixed dense / MoE / SSM, 3 shared models
# ---------------------------------------------------------------------------

ARCHES = ["gemma3-1b", "grok-1-314b", "mamba2-2.7b"]
# 8-tenant fleet: 4 dense, 2 expert-parallel MoE (grok smoke has
# num_experts=4 — divides mesh sizes 2 and 4), 2 SSM
FLEET = ["gemma3-1b", "gemma3-1b", "gemma3-1b", "gemma3-1b",
         "grok-1-314b", "grok-1-314b", "mamba2-2.7b", "mamba2-2.7b"]


@pytest.fixture(scope="module")
def models():
    out = {}
    for i, arch in enumerate(ARCHES):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(i + 1)))
    return out


@pytest.fixture(scope="module")
def fleet_factory(models):
    def factory(names=None):
        names = names if names is not None else [f"t{i}" for i in
                                                 range(len(FLEET))]
        return [Tenant(name, *models[arch], cache_len=32, max_batch=2)
                for name, arch in zip(names, FLEET)]
    return factory


def _fleet_trace():
    names = [f"t{i}" for i in range(len(FLEET))]
    return make_trace(names, rate_hz=1e4, n_per_tenant=2, prompt_len=6,
                      max_new_tokens=3, slo_s=1.0)


def _tokens(report):
    return {r.req_id: tuple(r.tokens_out or ())
            for r in report.requests}


# ---------------------------------------------------------------------------
# satellite 3: token bit-identity across mesh sizes + vs isolated runs
# ---------------------------------------------------------------------------

def test_fleet_tokens_bit_identical_across_mesh_sizes(fleet_factory):
    """The same mixed fleet serves token-bit-identically on 1, 2 and 4
    modeled devices, and matches each tenant running ISOLATED in its own
    single-device engine — placement must never leak into the math."""
    outs = {}
    for n in (1, 2, 4):
        eng = ServingEngine(fleet_factory(), mode="vliw", num_devices=n,
                            certify=True)
        rep = eng.run(_fleet_trace())
        assert rep.unfinished == 0
        outs[n] = _tokens(rep)
        assert all(len(t) == 3 for t in outs[n].values())
    assert outs[1] == outs[2] == outs[4]

    # isolated oracle: each tenant alone, its own engine and sub-trace
    isolated = {}
    trace = _fleet_trace()
    for tenant in fleet_factory():
        sub = [r for r in trace if r.tenant == tenant.name]
        # re-base arrivals on copies; identity (req_id) is unchanged
        t0 = sub[0].arrival_t
        sub = [dataclasses.replace(r, arrival_t=r.arrival_t - t0)
               for r in sub]
        eng = ServingEngine([tenant], mode="vliw")
        isolated.update(_tokens(eng.run(sub)))
    assert isolated == outs[1]


def test_mesh_run_reports_per_device_accounting(fleet_factory):
    eng = ServingEngine(fleet_factory(), mode="vliw", num_devices=4,
                        certify=True)
    rep = eng.run(_fleet_trace())
    assert rep.num_devices == 4
    assert len(rep.device_time_s) == len(rep.device_busy_s) == 4
    # every device got work (8 tenants, greedy fill) and the makespan is
    # the max device clock
    assert all(b > 0 for b in rep.device_busy_s)
    assert rep.modeled_time_s == pytest.approx(max(rep.device_time_s))
    assert rep.device_skew >= 1.0
    assert len(rep.device_util) == 4
    # MoE expert parallelism: grok spans the mesh (4 % 4 == 0), so the
    # cross-device all-to-all charge must be visible, not free
    assert rep.jit.collective_time_s > 0.0


def test_mesh_not_slower_and_no_cross_device_groups(fleet_factory):
    # saturating trace (near-simultaneous arrivals): an arrival-dominated
    # trace idles every mesh size equally, so the parallelism win only
    # shows when the fleet actually queues
    names = [f"t{i}" for i in range(len(FLEET))]
    sat = make_trace(names, rate_hz=1e9, n_per_tenant=2, prompt_len=6,
                     max_new_tokens=8, slo_s=1.0)
    reps = {}
    for n in (1, 4):
        eng = ServingEngine(fleet_factory(), mode="vliw", num_devices=n,
                            certify=True)
        reps[n] = (eng.run(sat), eng.last_trace)
    rep4, trace4 = reps[4]
    rep1, _ = reps[1]
    assert rep4.modeled_time_s < rep1.modeled_time_s
    # a coalesced group never mixes devices (structural: coalesce_key
    # leads with op.device; re-checked here off the recorded trace)
    for d in trace4.dispatches:
        assert len({op.device for op in d.ops}) == 1
        assert all(op.device == d.device for op in d.ops)
    # coalescing still happens WITHIN devices
    assert rep4.jit.coalesced_groups > 0


# ---------------------------------------------------------------------------
# satellite 3: placement determinism + load-skew bound
# ---------------------------------------------------------------------------

def test_placement_deterministic_and_skew_bounded(fleet_factory):
    assignments = []
    for _ in range(2):
        eng = ServingEngine(fleet_factory(), mode="vliw", num_devices=4)
        eng.run(_fleet_trace())
        assignments.append({n: (p.device, p.expert_span)
                            for n, p in eng.placement.assignments.items()})
        # greedy LPT-style guarantee: no device exceeds the ideal share
        # plus one tenant
        pol = eng.placement
        assert max(pol.load) <= pol.load_bound() + 1e-12
        assert pol.skew() >= 1.0
        # 8 tenants over 4 devices: greedy least-loaded fills every device
        assert {p.device for p in pol.assignments.values()} == {0, 1, 2, 3}
    assert assignments[0] == assignments[1]
    # the grok tenants span the mesh (4 | 4), dense/ssm stay local
    spans = {n: s for n, (_, s) in assignments[0].items()}
    assert spans["t4"] == spans["t5"] == 4
    assert all(spans[f"t{i}"] == 1 for i in (0, 1, 2, 3, 6, 7))


def test_expert_span_requires_divisibility():
    cfg = smoke_config("grok-1-314b")       # 4 experts
    pol3 = PlacementPolicy(DeviceSet.homogeneous(V100, 3))
    assert pol3.expert_span(cfg) == 1       # 4 % 3 != 0 -> local fallback
    pol2 = PlacementPolicy(DeviceSet.homogeneous(V100, 2))
    assert pol2.expert_span(cfg) == 2
    dense = smoke_config("gemma3-1b")
    assert pol2.expert_span(dense) == 1


# ---------------------------------------------------------------------------
# satellite 1: per-device conservation + placement-hazard mutation tests
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh_trace(fleet_factory):
    eng = ServingEngine(fleet_factory(), mode="vliw", num_devices=2,
                        certify=True)
    rep = eng.run(_fleet_trace())
    assert rep.jit.hazard_checks > 0 and rep.jit.hazard_violations == 0
    return eng.last_trace


def test_mesh_trace_certifies_clean(mesh_trace):
    trace = mesh_trace
    cert = certify_trace(trace, raise_on_violation=False)
    assert cert.checks > 0 and not cert.violations
    # per-device conservation: every request retires on the device that
    # admitted it
    assert trace.req_devices
    for rid, dev in trace.retire_devices.items():
        assert trace.req_devices[rid] == dev
    # both devices actually dispatched
    assert {d.device for d in trace.dispatches} == {0, 1}


def test_certifier_rejects_device_mixed_group(mesh_trace):
    trace = copy.deepcopy(mesh_trace)
    victim = next(d for d in trace.dispatches if d.ops)
    victim.ops[0].device = victim.device + 1      # op off its group
    cert = certify_trace(trace, raise_on_violation=False)
    assert any(isinstance(v, PlacementHazard) for v in cert.violations)


def test_certifier_rejects_offsite_dispatch(mesh_trace):
    trace = copy.deepcopy(mesh_trace)
    victim = next(d for d in trace.dispatches if d.ops)
    victim.device += 1        # whole group launched off its assignment
    cert = certify_trace(trace, raise_on_violation=False)
    assert any(isinstance(v, PlacementHazard) for v in cert.violations)


def test_conservation_rejects_cross_device_retire(mesh_trace):
    trace = copy.deepcopy(mesh_trace)
    rid = next(iter(trace.retire_devices))
    trace.retire_devices[rid] = trace.req_devices[rid] + 1
    violations = check_conservation(trace, raise_on_violation=False)
    assert any(isinstance(v, PlacementHazard) for v in violations)


# ---------------------------------------------------------------------------
# satellite 2 regressions: device id in block-plan memo + weight-cache keys
# ---------------------------------------------------------------------------

def test_block_plan_memo_is_device_keyed():
    """Two per-device coalescers SHARE one block-plan memo (the VLIWJit owns
    a single PlanCache); before the fix the memo key carried only the shape
    signature, so a heterogeneous mesh served device 0's modeled latency to
    device 1."""
    memo = PlanCache(64)
    c_fast = Coalescer(CostModel(TPUV5E), memo=memo, device_id=0)
    c_slow = Coalescer(CostModel(V100), memo=memo, device_id=1)

    def ops_on(device):
        ops = []
        for i in range(2):
            op = make_op(i, "gemv", GemmShape(m=4, n=256, k=128))
            op.device = device
            ops.append(op)
        return ops

    t0 = c_fast.plan(ops_on(0)).est_time_s
    t1 = c_slow.plan(ops_on(1)).est_time_s
    assert t0 != t1        # pre-fix: memo hit returned device 0's plan
    # memo still serves within a device
    assert c_fast.plan(ops_on(0)).est_time_s == t0


def test_weight_cache_is_device_keyed():
    """The packed-weight cache is shared across devices through one
    executor; each device stages its own resident copy. Before the fix the
    second device HIT device 0's entry (one modeled HBM residency serving
    two devices for free)."""
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    # one set of operand ARRAYS for every call: the cache guards on weight
    # identity (hot-swap invalidation), so fresh arrays would read as a
    # weight swap rather than a device-key miss
    probs = [(jax.random.normal(jax.random.PRNGKey(2 * i), (4, 128),
                                jnp.float32),
              jax.random.normal(jax.random.PRNGKey(2 * i + 1), (128, 256),
                                jnp.float32)) for i in range(2)]

    def fresh_ops():
        ops = []
        for i, (a, w) in enumerate(probs):
            op = make_op(i, "gemv", GemmShape(m=4, n=256, k=128))
            op.payload = (a, w, ("w", i))
            ops.append(op)
        return ops

    out0 = ex.execute(fresh_ops(), device=0)
    misses0 = ex.stats.weight_misses
    ex.execute(fresh_ops(), device=0)              # same device: cache hit
    assert ex.stats.weight_misses == misses0
    assert ex.stats.weight_hits > 0
    out1 = ex.execute(fresh_ops(), device=1)       # new device: must stage
    assert ex.stats.weight_misses > misses0
    for a, b in zip(out0, out1):                   # same math either way
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
