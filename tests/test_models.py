"""Model-substrate correctness: prefill/decode vs full forward, SSD duality,
chunked attention, chunked CE, MoE semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, smoke_config
from repro.configs.base import MoEConfig, SSMConfig
from repro.models import Model
from repro.models import attention as A
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib


def _ample_capacity(cfg):
    if cfg.moe:
        return dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.num_experts, cfg.moe.top_k,
                               capacity_factor=8.0))
    return cfg


def _batch(cfg, rng, B=2, S=16, labels=False):
    b = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if labels:
        b["labels"] = b["tokens"]
    if cfg.arch_type == "vlm":
        b["patch_embeds"] = 0.1 * jax.random.normal(
            rng, (B, cfg.num_patch_tokens, cfg.d_model))
    if cfg.is_encdec:
        b["frames"] = 0.1 * jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch, rng):
    """prefill + decode_step == full forward on the extended sequence
    (MoE archs get ample capacity so drops don't differ between paths)."""
    cfg = _ample_capacity(smoke_config(arch))
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    B, S, CL = 2, 16, 32
    batch = _batch(cfg, rng, B, S)
    logits_pre, cache = m.prefill(params, batch, cache_len=CL)
    fb = dict(batch, labels=batch["tokens"])
    logits_full, _ = m.forward(params, fb)
    np.testing.assert_allclose(logits_pre, logits_full[:, -1:],
                               rtol=1e-4, atol=1e-4)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    logits_dec, cache2 = m.decode_step(params, tok, cache)
    fb2 = dict(batch)
    fb2["tokens"] = jnp.concatenate([batch["tokens"], tok], 1)
    fb2["labels"] = fb2["tokens"]
    logits_full2, _ = m.forward(params, fb2)
    np.testing.assert_allclose(logits_dec, logits_full2[:, -1:],
                               rtol=1e-3, atol=1e-3)
    assert (cache2["pos"] == cache["pos"] + 1).all()


def test_continuous_batching_mixed_positions(rng):
    """Per-row positions: a batch whose rows are at different depths decodes
    identically to each row decoded alone."""
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    CL = 32
    # row 0 prefilled with 10 tokens, row 1 with 5
    b0 = {"tokens": jax.random.randint(rng, (1, 10), 0, cfg.vocab_size)}
    b1 = {"tokens": jax.random.randint(jax.random.fold_in(rng, 1), (1, 5),
                                       0, cfg.vocab_size)}
    _, c0 = m.prefill(params, b0, cache_len=CL)
    _, c1 = m.prefill(params, b1, cache_len=CL)
    # merge into one 2-row cache
    merged = {
        "pos": jnp.concatenate([c0["pos"], c1["pos"]]),
        "layers": jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=1),
            c0["layers"], c1["layers"]),
    }
    tok = jax.random.randint(jax.random.fold_in(rng, 2), (2, 1), 0,
                             cfg.vocab_size)
    logits_merged, _ = m.decode_step(params, tok, merged)
    logits_0, _ = m.decode_step(params, tok[:1], c0)
    logits_1, _ = m.decode_step(params, tok[1:], c1)
    np.testing.assert_allclose(logits_merged[0], logits_0[0], rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(logits_merged[1], logits_1[0], rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# SSD (mamba-2)
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_recurrent_reference(rng):
    scfg = SSMConfig(d_state=16, head_dim=16, expand=2, chunk_size=8)
    p = ssm_lib.init_mamba(rng, 64, scfg, jnp.float32)
    u = 0.5 * jax.random.normal(rng, (2, 24, 64))
    yc = ssm_lib.ssd_chunked(p, u, scfg)
    yr = ssm_lib.ssd_reference(p, u, scfg)
    np.testing.assert_allclose(yc, yr, rtol=1e-4, atol=1e-4)


def test_ssd_prefill_state_continues_decode(rng):
    """State returned by chunked prefill must continue exactly."""
    scfg = SSMConfig(d_state=8, head_dim=16, expand=2, chunk_size=8)
    p = ssm_lib.init_mamba(rng, 32, scfg, jnp.float32)
    u = 0.5 * jax.random.normal(rng, (1, 16, 32))
    u_next = 0.5 * jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 32))
    _, state = ssm_lib.ssd_chunked(p, u, scfg, return_state=True)
    y_step, _ = ssm_lib.ssd_decode_step(p, u_next, state, scfg)
    y_full = ssm_lib.ssd_chunked(p, jnp.concatenate([u, u_next], 1), scfg)
    np.testing.assert_allclose(y_step[:, 0], y_full[:, -1], rtol=1e-4,
                               atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(S=st.integers(3, 33), Q=st.sampled_from([4, 8, 16]))
def test_property_ssd_padding_invariance(S, Q):
    """SSD output must not depend on chunk-size padding."""
    rng = jax.random.PRNGKey(42)
    scfg = SSMConfig(d_state=8, head_dim=8, expand=2, chunk_size=Q)
    p = ssm_lib.init_mamba(rng, 16, scfg, jnp.float32)
    u = 0.3 * jax.random.normal(rng, (1, S, 16))
    y = ssm_lib.ssd_chunked(p, u, scfg)
    yr = ssm_lib.ssd_reference(p, u, scfg)
    assert y.shape == (1, S, 16)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# chunked attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,is_global", [(0, True), (256, False)])
def test_chunked_attention_matches_dense(window, is_global, rng, monkeypatch):
    B, S, H, Hkv, hd = 2, 2048, 4, 2, 32
    d = H * hd
    params = A.init_attention(rng, d, H, Hkv, hd, jnp.float32)
    x = 0.5 * jax.random.normal(rng, (B, S, d))
    kw = dict(num_heads=H, num_kv_heads=Hkv, head_dim=hd, rope_theta=1e4,
              is_global=is_global, window=window)
    out_chunked = A.attention_full(params, x, **kw)
    monkeypatch.setattr(A, "CHUNKED_THRESHOLD", 10 ** 9)
    out_dense = A.attention_full(params, x, **kw)
    np.testing.assert_allclose(out_chunked, out_dense, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_matches_dense_expert_sum(rng):
    """With ample capacity, sort-based dispatch == direct per-token expert
    evaluation."""
    cfg = MoEConfig(num_experts=4, top_k=2, capacity_factor=8.0)
    d, ff, T = 32, 64, 24
    p = moe_lib.init_moe(rng, d, ff, cfg, jnp.float32)
    x = jax.random.normal(rng, (T, d))
    y, aux = moe_lib.moe_ffn(p, x, cfg)
    # oracle: dense evaluation of every expert, combine with router weights
    w, e, _ = moe_lib.route(p["router"], x, cfg)
    gate = jax.nn.silu(jnp.einsum("td,edf->tef", x, p["w_gate"]))
    up = jnp.einsum("td,edf->tef", x, p["w_up"])
    outs = jnp.einsum("tef,efd->ted", gate * up, p["w_down"])
    want = jnp.zeros_like(x)
    for k in range(cfg.top_k):
        want += w[:, k:k + 1] * jnp.take_along_axis(
            outs, e[:, k][:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
    assert aux.shape == ()
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(rng):
    """At capacity_factor→0 every token is dropped → output ~ 0."""
    cfg = MoEConfig(num_experts=4, top_k=1, capacity_factor=1e-9)
    p = moe_lib.init_moe(rng, 16, 32, cfg, jnp.float32)
    x = jax.random.normal(rng, (8, 16))
    y, _ = moe_lib.moe_ffn(p, x, cfg)
    # capacity floor is top_k, so at most top_k tokens per expert survive
    assert jnp.sum(jnp.abs(y) > 0) <= 4 * 1 * 16


@settings(deadline=None, max_examples=10)
@given(T=st.integers(4, 40), E=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2))
def test_property_moe_combine_weights_normalized(T, E, k):
    rng = jax.random.PRNGKey(7)
    cfg = MoEConfig(num_experts=E, top_k=min(k, E), capacity_factor=8.0)
    p = moe_lib.init_moe(rng, 16, 32, cfg, jnp.float32)
    x = jax.random.normal(rng, (T, 16))
    w, e, aux = moe_lib.route(p["router"], x, cfg)
    np.testing.assert_allclose(jnp.sum(w, -1), jnp.ones(T), rtol=1e-5,
                               atol=1e-5)
    assert (e >= 0).all() and (e < E).all()
    assert jnp.isfinite(aux)
