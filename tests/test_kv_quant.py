"""int8 KV cache (§Perf K1): quantization round-trip accuracy, end-to-end
decode agreement with the bf16 cache, and cache size halving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.models.kvquant import dequantize, quantize


def test_quantize_roundtrip(rng):
    x = jax.random.normal(rng, (2, 4, 64, 32)) * 3.0
    q, s = quantize(x, scale_dtype=jnp.float32)
    assert q.dtype == jnp.int8 and s.shape == (2, 4, 64, 1)
    x2 = dequantize(q, s, dtype=jnp.float32)
    # symmetric int8: ~1% relative error per element
    rel = float(jnp.max(jnp.abs(x2 - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01


@pytest.mark.parametrize("arch", ["gemma3-1b", "yi-9b"])
def test_quantized_decode_agrees_with_bf16_cache(arch, rng):
    cfg = smoke_config(arch)
    m_fp = Model(cfg, param_dtype=jnp.float32)
    m_q8 = Model(cfg, param_dtype=jnp.float32, kv_quant=True)
    params = m_fp.init(rng)
    B, S, CL = 2, 12, 32
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    lg_fp, c_fp = m_fp.prefill(params, batch, cache_len=CL)
    lg_q8, c_q8 = m_q8.prefill(params, batch, cache_len=CL)
    assert c_q8["layers"]["k"].dtype == jnp.int8
    assert "k_scale" in c_q8["layers"]
    np.testing.assert_allclose(lg_q8, lg_fp, rtol=1e-4, atol=1e-4)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    for _ in range(3):
        lfp, c_fp = m_fp.decode_step(params, tok, c_fp)
        lq8, c_q8 = m_q8.decode_step(params, tok, c_q8)
        # int8 KV error stays small and greedy tokens agree
        err = float(jnp.max(jnp.abs(lq8 - lfp)))
        assert err < 0.05, err
        assert bool(jnp.all(jnp.argmax(lq8, -1) == jnp.argmax(lfp, -1)))
        tok = jnp.argmax(lfp[:, -1, :cfg.vocab_size], -1)[:, None]
        tok = tok.astype(jnp.int32)


def test_quantized_cache_is_half_size(rng):
    cfg = smoke_config("yi-9b")
    m = Model(cfg, param_dtype=jnp.bfloat16, kv_quant=True)
    c = m.init_cache(2, 64)
    hd = cfg.resolved_head_dim
    kv_bytes = c["layers"]["k"].nbytes + c["layers"]["v"].nbytes
    scale_bytes = c["layers"]["k_scale"].nbytes + c["layers"]["v_scale"].nbytes
    bf16_bytes = 2 * kv_bytes  # int8 -> bf16 would double
    assert kv_bytes + scale_bytes < 0.6 * bf16_bytes
    assert scale_bytes == kv_bytes * 2 // hd


def test_kv_quant_skipped_for_ssm_and_audio():
    for arch in ("mamba2-2.7b", "whisper-tiny"):
        m = Model(smoke_config(arch), kv_quant=True)
        assert not m.kv_quant
