"""Unit + property tests for the paper's core: cost model, clustering,
coalescer, autotuner, OoO scheduler, simulator."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (Autotuner, BlockConfig, Coalescer, CostModel,
                        GemmShape, OoOScheduler, SchedulerConfig, TPUV5E,
                        V100, cluster_greedy, make_op, make_requests,
                        simulate_space_mux, simulate_time_mux, simulate_vliw,
                        stream_program, zoo_population)
from repro.configs import REGISTRY, get_config

CM = CostModel(V100)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_gemm_time_positive_and_monotone_in_m():
    s1 = GemmShape(64, 1024, 1024)
    s2 = GemmShape(1024, 1024, 1024)
    assert 0 < CM.gemm_time(s1) <= CM.gemm_time(s2)


def test_coalescing_beats_time_multiplexing_for_small_gemms():
    s = GemmShape(m=784, n=128, k=1152, dtype_bytes=4)
    group = [s] * 8
    assert CM.time_multiplexed(group) / CM.coalesced_time(group) > 3.0


def test_paper_fig6_magnitudes():
    """Paper Fig. 6: 7.71x over time-slicing, 3.23x over Hyper-Q for a
    conv2_2-like SGEMM population. The calibrated model reproduces the
    magnitudes within 15%."""
    s = GemmShape(m=784, n=128, k=1152, dtype_bytes=4)
    group = [s] * 8
    t_c = CM.coalesced_time(group)
    assert CM.time_multiplexed(group) / t_c == pytest.approx(7.71, rel=0.15)
    assert CM.space_multiplexed(group) / t_c == pytest.approx(3.23, rel=0.15)


def test_paper_table1_direction():
    """Collaborative-tuned kernels beat greedy under co-tenancy (~1.25x)
    while paying an isolated-run regression (paper: 'small (20%)'; our
    model's occupancy story yields a larger one — see EXPERIMENTS.md)."""
    at = Autotuner(CM)
    r = at.tune(GemmShape(784, 512, 1152, dtype_bytes=4), co_tenants=2)
    assert 1.1 < r.multiplexed_speedup < 1.5
    assert 0.0 < r.isolated_regression < 0.8
    assert r.greedy != r.collaborative


def test_gemv_shared_coalescing_speedup():
    """Paper §5.3: coalescing RNN matvecs gives >2x over time-slicing."""
    coal = Coalescer(CM)
    g = GemmShape(m=1, n=4096, k=2048, dtype_bytes=4)
    ops = [make_op(i, "gemv", g, tag="x", model_id="lstm", seq_index=0)
           for i in range(3)]
    plan = coal.plan(ops)
    assert plan.shared_operand
    t_serial = CM.time_multiplexed([g] * 3, plan.block)
    assert t_serial / plan.est_time_s > 2.0


@settings(deadline=None, max_examples=30)
@given(m=st.integers(1, 2048), n=st.sampled_from([128, 512, 4096]),
       k=st.sampled_from([256, 1024, 4096]),
       g=st.integers(1, 16))
def test_property_coalescing_never_slower_than_serial(m, n, k, g):
    """Invariant: a zero-padding coalesced superkernel never loses to
    time-multiplexing the same work (launch amortization + packing)."""
    s = GemmShape(m, n, k)
    coal = Coalescer(CM, max_group=64)
    ops = [make_op(i, "gemm", s, tag="t", model_id=f"m{i}", seq_index=0)
           for i in range(g)]
    plan = coal.plan(ops)
    assert plan.est_time_s <= CM.time_multiplexed([s] * g, plan.block) * 1.001


# ---------------------------------------------------------------------------
# clustering (Fig. 7)
# ---------------------------------------------------------------------------

def test_cluster_padding_waste_bound():
    shapes = [GemmShape(1, n, k) for n, k in
              [(4096, 1024), (4000, 1024), (512, 512), (520, 500),
               (16384, 4096)]]
    clusters = cluster_greedy(shapes, max_waste=0.25)
    for c in clusters:
        assert c.padding_waste <= 0.25
    assert sum(len(c.members) for c in clusters) == len(shapes)


def test_zoo_population_clusters():
    """The 10-arch zoo's GEMM population concentrates into few clusters
    (the paper's Fig. 7 observation)."""
    rows = zoo_population(list(REGISTRY.values()), batch=1)
    shapes = [s for _, _, s in rows]
    clusters = cluster_greedy(shapes, max_waste=0.25)
    assert len(clusters) < len(shapes) / 2.0


@settings(deadline=None, max_examples=25)
@given(st.lists(st.tuples(st.integers(64, 8192), st.integers(64, 8192)),
                min_size=1, max_size=30))
def test_property_clustering_conserves_ops(nks):
    shapes = [GemmShape(1, n, k) for n, k in nks]
    clusters = cluster_greedy(shapes)
    assert sorted((s.n, s.k) for c in clusters for s in c.members) \
        == sorted((s.n, s.k) for s in shapes)
    for c in clusters:
        assert 0.0 <= c.padding_waste <= 0.25 + 1e-9


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _ops(n, stream0=0, m=64, slo=1.0):
    s = GemmShape(m, 512, 512)
    return [make_op(stream0 + i, "gemm", s, arrival_t=0.0,
                    deadline_t=slo, tag="t", model_id="m", seq_index=0)
            for i in range(n)]


def test_scheduler_drain_conserves_ops():
    coal = Coalescer(CM)
    sched = OoOScheduler(CM, coal)
    ops = _ops(10)
    sched.push(ops)
    plans = sched.drain()
    got = sorted(o.op_id for p in plans for o in p.ops)
    assert got == sorted(o.op_id for o in ops)


def test_scheduler_edf_priority():
    """The most urgent op is always in the dispatched group."""
    coal = Coalescer(CM)
    sched = OoOScheduler(CM, coal, SchedulerConfig(max_group=2))
    tight = make_op(0, "gemm", GemmShape(64, 512, 512), deadline_t=0.001)
    loose = [make_op(i + 1, "gemm", GemmShape(64, 512, 512), deadline_t=10.0)
             for i in range(5)]
    sched.push(loose + [tight])
    d = sched.decide(0.0)
    assert d.kind == "dispatch"
    assert tight in d.plan.ops


def test_scheduler_waits_only_with_slack_and_arrivals():
    coal = Coalescer(CM)
    sched = OoOScheduler(CM, coal)
    sched.push(_ops(1, slo=10.0))
    sched.next_arrival_t = 1e-5   # an arrival is imminent
    d = sched.decide(0.0)
    assert d.kind == "wait" and d.wait_until <= 10.0
    # without upcoming arrivals it must dispatch
    sched.next_arrival_t = math.inf
    d2 = sched.decide(0.0)
    assert d2.kind == "dispatch"


def test_scheduler_no_wait_past_latest_start():
    coal = Coalescer(CM)
    sched = OoOScheduler(CM, coal)
    ops = _ops(1, slo=1e-9)       # already past latest start
    sched.push(ops)
    sched.next_arrival_t = 0.5
    assert sched.decide(0.0).kind == "dispatch"


@settings(deadline=None, max_examples=20)
@given(n=st.integers(1, 40), m=st.sampled_from([1, 16, 256]))
def test_property_drain_groups_bounded(n, m):
    coal = Coalescer(CM, max_group=8)
    sched = OoOScheduler(CM, coal)
    sched.push(_ops(n, m=m))
    plans = sched.drain()
    assert all(1 <= p.num_problems <= 8 for p in plans)
    assert sum(p.num_problems for p in plans) == n


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

def test_simulator_policies_rank_as_paper_predicts():
    cfg = get_config("gemma3-1b")
    streams = [(cfg, 0.5, [i * 1e-4 for i in range(4)]) for _ in range(6)]
    reqs = make_requests(streams, batch=16)
    t = simulate_time_mux(reqs, CM)
    v = simulate_vliw(reqs, CM)
    assert v.throughput_rps > t.throughput_rps
    assert v.utilization > t.utilization
    assert set(v.latencies) == set(t.latencies)


def test_stream_program_order_and_deadlines():
    cfg = get_config("yi-9b")
    ops = stream_program(cfg, 0, batch=1, arrival_t=1.0, slo_s=0.2)
    assert ops[0].seq_index == 0
    assert all(b.seq_index == a.seq_index + 1
               for a, b in zip(ops, ops[1:]))
    assert all(op.deadline_t == pytest.approx(1.2) for op in ops)
    assert ops[-1].tag == "unembed"
