"""Non-dense tenants through the JIT (ISSUE 5 tentpole): MoE and SSM decode
steps compile to first-class KernelPrograms — template-vs-monolithic
equivalence per batch size and expert count, steady-state plan-cache hit
rates, weight hot-swap invalidation, cross-tenant expert-GEMM coalescing,
the mixed dense+MoE+SSM+int8-KV fleet staying token-identical across all
three serving modes, and the PlanCache byte-budget regressions for the
bigger stacked expert packs this path introduces."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, smoke_config
from repro.core.costmodel import GemmShape
from repro.core.jit import (VLIWJit, build_moe_decode_template,
                            build_ssm_decode_template, moe_program_cache_key,
                            ssm_program_cache_key)
from repro.core.kernelspec import make_op
from repro.core.plancache import PlanCache
from repro.core.dispatch import SuperkernelExecutor
from repro.models import Model
from repro.serving import ServeRequest, ServingEngine, Tenant


def _moe_cfg(num_experts: int):
    base = smoke_config("grok-1-314b")
    return dataclasses.replace(
        base, name=f"{base.name}-e{num_experts}",
        moe=MoEConfig(num_experts=num_experts, top_k=2))


def _setup(cfg, rng, B=2, S=12, CL=32):
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=CL)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (B, 1), 0,
                             cfg.vocab_size)
    return m, params, cache, tok


def _builder_for(cfg):
    return build_moe_decode_template if cfg.arch_type == "moe" \
        else build_ssm_decode_template


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


# ---------------------------------------------------------------------------
# template == monolithic decode_step, per batch size and expert count
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 2])
@pytest.mark.parametrize("num_experts", [2, 4])
def test_moe_template_matches_decode_step(batch, num_experts, rng):
    cfg = _moe_cfg(num_experts)
    m, params, cache, tok = _setup(cfg, rng, B=batch)
    want, want_cache = m.decode_step(params, tok, cache)
    template = build_moe_decode_template(m, params, batch)
    prog = template.bind(stream_id=0, tokens=tok, cache=cache)
    VLIWJit(max_group=8).run([prog])
    np.testing.assert_allclose(prog.env["logits"][:, None, :], want,
                               rtol=2e-4, atol=2e-4)
    # greedy tokens bit-identical to the monolithic step
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(prog.env["logits"], axis=-1)),
        np.asarray(jnp.argmax(want[:, -1], axis=-1)))
    for key in ("k", "v"):
        np.testing.assert_allclose(prog.env["cache"]["layers"][key],
                                   want_cache["layers"][key],
                                   rtol=2e-4, atol=2e-4)
    assert int(prog.env["cache"]["pos"][0]) == int(want_cache["pos"][0])


@pytest.mark.parametrize("batch", [1, 2])
def test_ssm_template_matches_decode_step(batch, rng):
    cfg = smoke_config("mamba2-2.7b")
    m, params, cache, tok = _setup(cfg, rng, B=batch)
    want, want_cache = m.decode_step(params, tok, cache)
    template = build_ssm_decode_template(m, params, batch)
    prog = template.bind(stream_id=0, tokens=tok, cache=cache)
    VLIWJit(max_group=8).run([prog])
    np.testing.assert_allclose(prog.env["logits"][:, None, :], want,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(prog.env["logits"], axis=-1)),
        np.asarray(jnp.argmax(want[:, -1], axis=-1)))
    np.testing.assert_allclose(prog.env["cache"]["layers"]["conv"],
                               want_cache["layers"]["conv"],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(prog.env["cache"]["layers"]["h"],
                               want_cache["layers"]["h"],
                               rtol=2e-4, atol=2e-4)
    assert int(prog.env["cache"]["pos"][0]) == int(want_cache["pos"][0])


@pytest.mark.parametrize("arch", ["grok-1-314b", "mamba2-2.7b"])
def test_template_bind_bit_identical_to_fresh_build(arch, rng):
    """Binding a cached template must be BIT-identical to building a fresh
    one — the plan cache can never change a single logit."""
    cfg = smoke_config(arch)
    m, params, cache, tok = _setup(cfg, rng)
    build = _builder_for(cfg)
    fresh = build(m, params, 2).bind(stream_id=0, tokens=tok, cache=cache)
    VLIWJit(max_group=8).run([fresh])
    template = build(m, params, 2)
    bound = template.bind(stream_id=0, tokens=tok, cache=cache)
    VLIWJit(max_group=8).run([bound])
    np.testing.assert_array_equal(np.asarray(bound.env["logits"]),
                                  np.asarray(fresh.env["logits"]))
    # second step from the SAME template: rebind tokens + cache only
    tok2 = jnp.argmax(bound.env["logits"], axis=-1).astype(jnp.int32)[:, None]
    fresh2 = build(m, params, 2).bind(stream_id=0, tokens=tok2,
                                      cache=fresh.env["cache"])
    VLIWJit(max_group=8).run([fresh2])
    bound2 = template.bind(stream_id=0, tokens=tok2,
                           cache=bound.env["cache"])
    VLIWJit(max_group=8).run([bound2])
    np.testing.assert_array_equal(np.asarray(bound2.env["logits"]),
                                  np.asarray(fresh2.env["logits"]))


def test_nondense_cache_keys_capture_identity(rng):
    cfg_moe, cfg_ssm = _moe_cfg(4), smoke_config("mamba2-2.7b")
    mm = Model(cfg_moe, param_dtype=jnp.float32)
    pm = mm.init(rng)
    ms = Model(cfg_ssm, param_dtype=jnp.float32)
    ps = ms.init(rng)
    cm, cs = mm.init_cache(2, 32), ms.init_cache(2, 32)
    assert moe_program_cache_key(mm, pm, 2, cm) \
        == moe_program_cache_key(mm, pm, 2, mm.init_cache(2, 32))
    assert moe_program_cache_key(mm, pm, 2, cm) \
        != moe_program_cache_key(mm, pm, 4, mm.init_cache(4, 32))
    assert ssm_program_cache_key(ms, ps, 2, cs) \
        != ssm_program_cache_key(ms, ps, 4, ms.init_cache(4, 32))
    # moe and ssm keys can never collide with each other or with dense
    assert moe_program_cache_key(mm, pm, 2, cm)[0] == "moe-decode"
    assert ssm_program_cache_key(ms, ps, 2, cs)[0] == "ssm-decode"


# ---------------------------------------------------------------------------
# serving: steady-state hit rate, hot-swap, cached-vs-uncached identity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fleet_models():
    out = {}
    for arch, seed in (("gemma3-1b", 1), ("grok-1-314b", 2),
                       ("mamba2-2.7b", 3)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    kvq = Model(smoke_config("gemma3-1b"), param_dtype=jnp.float32,
                kv_quant=True)
    out["int8-kv"] = (kvq, kvq.init(jax.random.PRNGKey(5)))
    return out


@pytest.mark.parametrize("arch", ["grok-1-314b", "mamba2-2.7b"])
def test_nondense_steady_state_hit_rate_and_cached_identity(arch,
                                                            fleet_models):
    m, p = fleet_models[arch]
    steps = 5   # decode steps per request (max_new_tokens - 1)
    trace = [ServeRequest(0, "a", 0.0, 8, steps + 1, 1.0)]
    reps = {}
    for cap in (128, 0):     # cached vs rebuild-per-step baseline
        eng = ServingEngine([Tenant("a", m, p, cache_len=32, max_batch=2)],
                            mode="vliw", plan_capacity=cap)
        reps[cap] = eng.run(trace)
    assert _tokens(reps[128]) == _tokens(reps[0])   # bit-identical tokens
    pc = reps[128].jit.plan_cache
    # miss only on the first step; every steady-state tick binds from cache
    assert pc.misses == 1
    assert pc.hits == steps - 1
    assert pc.hit_rate >= (steps - 1) / steps - 1e-9
    assert pc.invalidations == 0
    assert reps[128].jit.nondense_programs == steps
    # the expert/scan weight closures hand the executor STABLE arrays:
    # steady state must never read as a phantom weight hot-swap
    assert reps[128].jit.dispatch.weight_invalidations == 0
    assert reps[128].jit.dispatch.weight_hits > 0


def test_nondense_weight_hot_swap_invalidates(fleet_models):
    m, p_old = fleet_models["grok-1-314b"]
    p_new = Model(m.cfg, param_dtype=jnp.float32).init(jax.random.PRNGKey(77))
    trace1 = [ServeRequest(0, "a", 0.0, 8, 3, 1.0)]
    trace2 = [ServeRequest(1, "a", 0.0, 8, 3, 1.0)]
    eng = ServingEngine([Tenant("a", m, p_old, cache_len=32, max_batch=2)],
                        mode="vliw")
    eng.run(trace1)
    assert eng.jit.plan_cache.stats.invalidations == 0
    eng.tenants["a"].params = p_new          # weight hot-swap, same model
    rep_swapped = eng.run(trace2)
    assert eng.jit.plan_cache.stats.invalidations >= 1
    fresh = ServingEngine([Tenant("a", m, p_new, cache_len=32, max_batch=2)],
                          mode="vliw")
    rep_fresh = fresh.run(trace2)
    assert _tokens(rep_swapped) == _tokens(rep_fresh)


def test_mixed_fleet_three_modes_token_identity(fleet_models):
    """Acceptance core: a dense + MoE + SSM + int8-KV fleet generates
    bit-identical per-tenant tokens in all three modes AND vs each tenant
    running alone, with the MoE/SSM tenants dispatching through the JIT
    (nondense_programs >= 1) instead of the batched fallback."""
    names = {"dense": "gemma3-1b", "moe": "grok-1-314b",
             "ssm": "mamba2-2.7b", "int8": "int8-kv"}

    def tenants(only=None):
        return [Tenant(n, *fleet_models[a], cache_len=32, max_batch=2)
                for n, a in names.items() if only is None or n == only]

    trace = [ServeRequest(i, n, i * 1e-6, 8, 3, 10.0)
             for i, n in enumerate(names)]
    toks = {}
    for mode in ("time", "batched", "vliw"):
        eng = ServingEngine(tenants(), mode=mode)
        rep = eng.run(trace)
        toks[mode] = {r.tenant: r.tokens_out for r in rep.requests}
        assert all(len(t) == 3 for t in toks[mode].values())
        if mode == "vliw":
            # MoE and SSM steps went through the JIT, not the fallback
            assert rep.jit.nondense_programs >= 1
            assert rep.jit.superkernels > 0
    assert toks["time"] == toks["batched"] == toks["vliw"]
    # per-tenant isolation: co-tenants cannot change anyone's tokens
    for name in names:
        eng = ServingEngine(tenants(only=name), mode="batched")
        rep = eng.run([r for r in trace if r.tenant == name])
        (req,) = rep.requests
        assert req.tokens_out == toks["vliw"][name]


# ---------------------------------------------------------------------------
# cross-tenant expert-GEMM coalescing
# ---------------------------------------------------------------------------

def test_two_moe_tenants_coalesce_expert_gemms(rng):
    """Two MoE tenants in lockstep: their per-expert FFN GEMMs (distinct
    weights) coalesce into shared superkernel groups — counted by
    JitStats.expert_coalesced — with per-tenant results unchanged."""
    cfg = _moe_cfg(4)
    m1, p1, c1, t1 = _setup(cfg, rng)
    m2, p2, c2, t2 = _setup(cfg, jax.random.fold_in(rng, 1))
    want1, _ = m1.decode_step(p1, t1, c1)
    want2, _ = m2.decode_step(p2, t2, c2)
    prog1 = build_moe_decode_template(m1, p1, 2).bind(
        stream_id=0, tokens=t1, cache=c1)
    prog2 = build_moe_decode_template(m2, p2, 2).bind(
        stream_id=1, tokens=t2, cache=c2)
    stats = VLIWJit(max_group=8).run([prog1, prog2])
    assert stats.expert_coalesced >= 1
    assert stats.mean_group > 1.0
    np.testing.assert_allclose(prog1.env["logits"][:, None, :], want1,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(prog2.env["logits"][:, None, :], want2,
                               rtol=2e-4, atol=2e-4)


def test_same_params_moe_tenants_share_expert_operands(rng):
    """Two tenants serving literally the same MoE params: each coalesced
    expert group carries ONE weight key, so the superkernel loads the
    expert's weights once (the shared-operand regime)."""
    cfg = _moe_cfg(4)
    m, params, cache, tok = _setup(cfg, rng)
    cache2 = jax.tree_util.tree_map(lambda a: a, cache)  # fresh array tree
    template = build_moe_decode_template(m, params, 2)
    prog1 = template.bind(stream_id=0, tokens=tok, cache=cache)
    prog2 = template.bind(stream_id=1, tokens=tok, cache=cache2)
    stats = VLIWJit(max_group=8).run([prog1, prog2])
    assert stats.shared_dispatches > 0
    np.testing.assert_array_equal(np.asarray(prog1.env["logits"]),
                                  np.asarray(prog2.env["logits"]))


# ---------------------------------------------------------------------------
# PlanCache byte budget with stacked expert packs (satellite regression)
# ---------------------------------------------------------------------------

def _rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _expert_ops(slot: int, n_experts: int, seed0: int, m: int = 2,
                k: int = 128, n: int = 256):
    """One MoE expert-GEMM group: ``n_experts`` problems with distinct
    per-expert weights, expert index in the weight key."""
    a = _rand(0, (m, k))
    ops = []
    for e in range(n_experts):
        op = make_op(slot, "gemv", GemmShape(m=m, n=n, k=k),
                     tag="expert_gate", seq_index=e)
        op.payload = (a, _rand(seed0 + e, (k, n)), ("moe", slot, "w_gate", e))
        ops.append(op)
    return ops


def test_byte_budget_counts_full_stacked_expert_operand():
    """The cached value is the FULL stacked expert operand — G bucketed to
    a power of two — and ``PlanCache.bytes`` must account every byte of
    it, not just the live experts' slices."""
    cache = PlanCache(capacity=64, byte_capacity=1 << 30)
    ex = SuperkernelExecutor(cache, bm=8)
    ex.execute(_expert_ops(0, n_experts=3, seed0=10))   # G=3 -> G_pad=4
    expected = 4 * 128 * 256 * 4                        # G_pad x K x N fp32
    assert cache.bytes == expected
    assert cache.bytes == sum(
        int(getattr(e.value, "nbytes", 0)) for e in cache._entries.values())


def test_byte_budget_evicts_expert_packs_lru():
    """Expert packs past the byte budget evict LRU-first: the oldest
    slots' packs go, the newest stay resident (re-dispatching the newest
    hits, the oldest misses)."""
    pack = 4 * 128 * 256 * 4
    cache = PlanCache(capacity=64, byte_capacity=3 * pack + 1)
    ex = SuperkernelExecutor(cache, bm=8)
    groups = [_expert_ops(i, n_experts=3, seed0=100 + 10 * i)
              for i in range(5)]
    for g in groups:
        ex.execute(g)
    assert cache.bytes <= 3 * pack + 1
    assert cache.stats.evictions == 2            # slots 0 and 1 reclaimed
    misses0 = ex.stats.weight_misses
    ex.execute(groups[-1])                       # newest: resident -> hit
    assert ex.stats.weight_misses == misses0
    assert ex.stats.weight_hits >= 1
    ex.execute(groups[0])                        # oldest: evicted -> miss
    assert ex.stats.weight_misses == misses0 + 1


def test_oversized_pack_passes_through_without_wiping_cache():
    """Regression: a pack bigger than the WHOLE byte budget used to evict
    every resident entry and then sit over budget anyway (pinned as the
    'newest'). It must pass through uncached, leaving the other tenants'
    packs intact."""
    small = _rand(1, (64, 64))                   # 16 KiB
    cache = PlanCache(capacity=64, byte_capacity=4 * small.nbytes)
    for i in range(3):
        cache.get_or_build(("small", i), lambda: small)
    bytes0 = cache.bytes
    giant = _rand(2, (512, 512))                 # 1 MiB >> budget
    out = cache.get_or_build(("giant",), lambda: giant)
    assert out is giant                          # value still served
    assert ("giant",) not in cache               # ...but not retained
    assert len(cache) == 3 and cache.bytes == bytes0
    assert cache.stats.evictions == 0            # nothing wiped
    # and the smalls still hit
    hits0 = cache.stats.hits
    cache.get_or_build(("small", 0), lambda: None)
    assert cache.stats.hits == hits0 + 1
