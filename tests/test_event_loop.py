"""The event-driven OoO runtime: mid-flight admission, the stagger/WAIT
branch on the real serving path, SLO eviction, and the livelock clamp."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import (Coalescer, CostModel, GemmShape, OoOScheduler,
                        SchedulerConfig, V100, make_op)
from repro.core.jit import (JitStats, StreamStat, VLIWJit,
                            build_dense_decode_program)
from repro.models import Model
from repro.serving import ServingEngine, Tenant, two_wave_trace

CM = CostModel(V100)


# ---------------------------------------------------------------------------
# scheduler units: livelock clamp + SLO eviction
# ---------------------------------------------------------------------------

def _sched(cfg=SchedulerConfig()):
    return OoOScheduler(CM, Coalescer(CM), cfg)


def test_wait_never_schedules_into_the_past():
    """A stale/elapsed next_arrival_t must not produce wait_until <= now —
    the dispatch loop advances time via ``now = wait_until`` and would
    otherwise spin forever."""
    for stale in (-1.0, 0.0):
        sched = _sched()
        sched.push([make_op(0, "gemm", GemmShape(64, 512, 512),
                            deadline_t=10.0)])
        sched.next_arrival_t = stale
        d = sched.decide(0.0)
        assert d.kind == "dispatch"
    # a genuinely future arrival still triggers the stagger branch, and the
    # wait target is strictly in the future
    sched = _sched()
    sched.push([make_op(0, "gemm", GemmShape(64, 512, 512), deadline_t=10.0)])
    sched.next_arrival_t = 1e-5
    d = sched.decide(0.0)
    assert d.kind == "wait" and d.wait_until > 0.0


def test_scheduler_evicts_missed_stragglers():
    """An op whose request deadline already passed is demoted out of the EDF
    anchor set (counted as an eviction) so it cannot cascade misses; it still
    runs once the healthy work has been anchored."""
    sched = _sched()
    late = make_op(0, "gemm", GemmShape(64, 512, 512), deadline_t=0.001)
    fresh = make_op(1, "gemm", GemmShape(64, 1024, 1024), deadline_t=10.0)
    sched.push([late, fresh])
    d = sched.decide(1.0)          # late's deadline is long gone
    assert d.kind == "dispatch"
    assert sched.evictions == 1
    assert all(op.shape.n == 1024 for op in d.plan.ops)  # fresh anchors
    d2 = sched.decide(1.0)         # the straggler still executes
    assert d2.kind == "dispatch" and d2.plan.ops == [late]
    assert sched.evictions == 1    # demotion is counted once


def test_jitstats_merge():
    a = JitStats(superkernels=2, ops_executed=5, groups=StreamStat.of([2, 3]),
                 padding_waste=StreamStat.of([0.1]), modeled_time_s=1.0,
                 modeled_serial_time_s=2.0, shared_dispatches=1, waits=1,
                 evictions=2, mid_flight_admissions=3)
    b = JitStats(superkernels=1, ops_executed=1, groups=StreamStat.of([1]),
                 padding_waste=StreamStat.of([0.0]), modeled_time_s=0.5,
                 modeled_serial_time_s=0.5, shared_dispatches=0, waits=2,
                 evictions=0, mid_flight_admissions=1)
    out = a.merge(b)
    assert out is a
    assert a.superkernels == 3 and a.ops_executed == 6
    # groups/padding_waste are streaming aggregates, not unbounded lists —
    # the merge must fold count/sum/min/max and preserve mean_group
    assert a.groups == StreamStat.of([2, 3, 1])
    assert a.mean_group == pytest.approx(2.0)
    assert a.padding_waste == StreamStat.of([0.1, 0.0])
    assert (a.padding_waste.min, a.padding_waste.max) == (0.0, 0.1)
    assert a.modeled_time_s == 1.5 and a.modeled_serial_time_s == 2.5
    assert a.shared_dispatches == 1 and a.waits == 3
    assert a.evictions == 2 and a.mid_flight_admissions == 4


# ---------------------------------------------------------------------------
# JIT-level mid-flight admission
# ---------------------------------------------------------------------------

def test_jit_mid_flight_arrival_matches_monolithic(rng):
    """A program admitted mid-flight (via a deferred factory) computes
    exactly what the monolithic decode computes, and is counted."""
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    batch = {"tokens": jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=32)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (2, 1), 0,
                             cfg.vocab_size)
    want, _ = m.decode_step(params, tok, cache)

    prog1 = build_dense_decode_program(m, params, tok, cache, stream_id=0)
    made = []

    def factory():
        p = build_dense_decode_program(m, params, tok, cache, stream_id=1)
        made.append(p)
        return p

    stats = VLIWJit(max_group=8).run([prog1], arrivals=[(1e-6, factory)])
    assert made, "deferred arrival factory was never invoked"
    assert stats.mid_flight_admissions == 1
    for prog in (prog1, made[0]):
        np.testing.assert_allclose(prog.env["logits"][:, None, :], want,
                                   rtol=2e-4, atol=2e-4)


def test_same_arch_distinct_weights_do_not_share_operands(rng):
    """Two tenants of the same architecture but independently initialized
    weights coalesce WITHOUT operand sharing — each stream's logits must
    come from its own weights (regression: the weight key once ignored
    params identity, silently computing both streams with one tenant's
    weight matrix)."""
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    pa = m.init(rng)
    pb = m.init(jax.random.fold_in(rng, 123))
    batch = {"tokens": jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)}
    _, cache = m.prefill(pa, batch, cache_len=32)
    _, cache_b = m.prefill(pb, batch, cache_len=32)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (2, 1), 0,
                             cfg.vocab_size)
    prog_a = build_dense_decode_program(m, pa, tok, cache, stream_id=0)
    prog_b = build_dense_decode_program(m, pb, tok, cache_b, stream_id=1)
    stats = VLIWJit(max_group=8).run([prog_a, prog_b])
    assert stats.shared_dispatches == 0    # distinct weights: no sharing
    assert stats.mean_group == pytest.approx(2.0)  # but still coalesced
    want_a, _ = m.decode_step(pa, tok, cache)
    want_b, _ = m.decode_step(pb, tok, cache_b)
    np.testing.assert_allclose(prog_a.env["logits"][:, None, :], want_a,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(prog_b.env["logits"][:, None, :], want_b,
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# engine-level: live admission + the WAIT regression (paper §5.2)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_models():
    out = {}
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    return out


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def test_midflight_admission_bit_identical_to_batched(dense_models):
    """A request admitted while another tenant is mid-superkernel-stream
    yields exactly the tokens the round-synchronous batched engine yields."""
    m1, p1 = dense_models["gemma3-1b"]
    m2, p2 = dense_models["yi-9b"]

    def tenants():
        return [Tenant("a", m1, p1, cache_len=32, max_batch=2),
                Tenant("b", m2, p2, cache_len=32, max_batch=2)]

    probe = ServingEngine(tenants(), mode="vliw")
    gap = 1.5 * probe._prefill_time(m1.cfg, 8)
    trace = two_wave_trace(["a"], ["b"], gap, prompt_len=8,
                           max_new_tokens=4, slo_s=1.0)
    reps = {}
    for mode in ("batched", "vliw"):
        eng = ServingEngine(tenants(), mode=mode)
        reps[mode] = eng.run(trace)
    assert _tokens(reps["batched"]) == _tokens(reps["vliw"])
    # wave 2 joined a non-empty op pool, between dispatches
    assert reps["vliw"].jit.mid_flight_admissions > 0


def test_same_tenant_midflight_arrival_bit_identical(dense_models):
    """A second request for the SAME tenant arriving while that tenant's
    program is inflight must not clobber the inflight step's cache: it
    joins at the tenant's next step boundary, and tokens stay identical to
    batched mode (regression: the prefill used to be overwritten by the
    completing program's write-back)."""
    m1, p1 = dense_models["gemma3-1b"]

    def tenants():
        return [Tenant("a", m1, p1, cache_len=32, max_batch=2)]

    probe = ServingEngine(tenants(), mode="vliw")
    gap = 1.5 * probe._prefill_time(m1.cfg, 8)
    trace = two_wave_trace(["a"], ["a"], gap, prompt_len=8,
                           max_new_tokens=4, slo_s=1.0)
    reps = {}
    for mode in ("batched", "vliw"):
        eng = ServingEngine(tenants(), mode=mode)
        reps[mode] = eng.run(trace)
    assert _tokens(reps["batched"]) == _tokens(reps["vliw"])
    assert all(len(r.tokens_out) == 4 for r in reps["vliw"].requests)


def test_deferred_tenant_does_not_block_other_admissions(dense_models):
    """A due request deferred because its tenant's program is inflight must
    not head-of-line-block other tenants' due requests: both a same-tenant
    and a cross-tenant request arrive mid-step, the cross-tenant one joins
    the live pool immediately, and tokens still match batched mode."""
    m1, p1 = dense_models["gemma3-1b"]
    m2, p2 = dense_models["yi-9b"]

    def tenants():
        return [Tenant("a", m1, p1, cache_len=32, max_batch=2),
                Tenant("b", m2, p2, cache_len=32, max_batch=2)]

    probe = ServingEngine(tenants(), mode="vliw")
    gap = 1.5 * probe._prefill_time(m1.cfg, 8)
    # wave 2: a second "a" request (deferred: a is inflight) ordered BEFORE
    # a "b" request with the same arrival time
    trace = two_wave_trace(["a"], ["a", "b"], gap, prompt_len=8,
                           max_new_tokens=4, slo_s=1.0)
    reps = {}
    for mode in ("batched", "vliw"):
        eng = ServingEngine(tenants(), mode=mode)
        reps[mode] = eng.run(trace)
    assert _tokens(reps["batched"]) == _tokens(reps["vliw"])
    assert all(len(r.tokens_out) == 4 for r in reps["vliw"].requests)
    # "b" joined the live pool while "a" was mid-stream
    assert reps["vliw"].jit.mid_flight_admissions > 0


def test_staged_arrivals_trigger_wait_and_improve_packing(dense_models):
    """Acceptance: on a staged two-wave trace the real serving path takes at
    least one WAIT decision, and waiting strictly improves the mean
    coalesced group size over the never-wait run of the same trace."""
    m1, p1 = dense_models["gemma3-1b"]

    def tenants():
        return [Tenant("t1", m1, p1, cache_len=32, max_batch=2),
                Tenant("t2", m1, p1, cache_len=32, max_batch=2)]

    probe = ServingEngine(tenants(), mode="vliw")
    gap = 1.2 * probe._prefill_time(m1.cfg, 8)
    trace = two_wave_trace(["t1"], ["t2"], gap, prompt_len=8,
                           max_new_tokens=6, slo_s=1.0)
    wait_cfg = SchedulerConfig(min_wait_gain_s=0.0, max_wait_s=0.05)
    nowait_cfg = SchedulerConfig(max_wait_s=0.0)   # stagger branch disabled
    reps = {}
    for name, sc in (("wait", wait_cfg), ("nowait", nowait_cfg)):
        eng = ServingEngine(tenants(), mode="vliw", sched_cfg=sc)
        reps[name] = eng.run(trace)
    w, n = reps["wait"].jit, reps["nowait"].jit
    assert w.waits >= 1
    assert n.waits == 0
    assert w.mean_group > n.mean_group       # strictly better packing
    assert w.superkernels < n.superkernels   # fewer, fuller dispatches
    # staggering must not change any request's tokens
    assert _tokens(reps["wait"]) == _tokens(reps["nowait"])
    # SLOs were generous: nothing should have been evicted
    assert w.evictions == 0


def test_missed_slo_requests_counted_as_evictions(dense_models):
    """Requests whose deadline is unmeetable get demoted (evictions > 0) but
    still complete with correct-length outputs."""
    m1, p1 = dense_models["gemma3-1b"]
    tenants = [Tenant("t1", m1, p1, cache_len=32, max_batch=2),
               Tenant("t2", m1, p1, cache_len=32, max_batch=2)]
    trace = two_wave_trace(["t1"], ["t2"], 1e-7, prompt_len=8,
                           max_new_tokens=3, slo_s=1e-9)  # hopeless SLO
    eng = ServingEngine(tenants, mode="vliw")
    rep = eng.run(trace)
    # one demotion per missed request (per stream×deadline), not per GEMM op
    assert rep.jit.evictions == 2
    assert all(len(r.tokens_out) == 3 for r in rep.requests)
    assert rep.slo_attainment == 0.0
