"""Single-device lowering smoke: the exact dry-run step builders lower and
compile on a 1×1 mesh with reduced configs — catches step/sharding wiring
regressions without the 512-device flag (which tests must not set)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, smoke_config
from repro.configs.base import InputShape
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        param_shardings)
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step


SMALL_SHAPES = {
    "train": InputShape("train_small", 64, 2, "train"),
    "prefill": InputShape("prefill_small", 64, 2, "prefill"),
    "decode": InputShape("decode_small", 64, 2, "decode"),
}


@pytest.mark.parametrize("arch", ["gemma3-1b", "grok-1-314b", "mamba2-2.7b",
                                  "whisper-tiny"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_lower_compile_on_host_mesh(arch, kind, rng):
    cfg = smoke_config(arch)
    shape = SMALL_SHAPES[kind]
    mesh = make_host_mesh()
    model = Model(cfg, param_dtype=jnp.float32, remat=(kind == "train"))
    with mesh:
        p_sh = param_shardings(model, mesh, rng)
        p_shape = jax.eval_shape(model.init, rng)
        in_specs = model.input_specs(shape)
        b_sh = batch_shardings(model, shape, mesh)
        if kind == "train":
            opt_shape = jax.eval_shape(init_opt_state, p_shape)
            step = make_train_step(model, OptimizerConfig())
            compiled = jax.jit(step).lower(p_shape, opt_shape,
                                           in_specs).compile()
        elif kind == "prefill":
            compiled = jax.jit(
                lambda p, b: model.prefill(p, b, cache_len=shape.seq_len)
            ).lower(p_shape, in_specs).compile()
        else:
            c_sh = cache_shardings(model, in_specs["cache"], mesh, shape)
            compiled = jax.jit(model.decode_step).lower(
                p_shape, in_specs["tokens"], in_specs["cache"]).compile()
    assert compiled.cost_analysis() is not None
