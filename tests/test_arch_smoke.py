"""Per-architecture smoke tests: reduced variants of every assigned family
run one forward/train step and one prefill→decode step on CPU, asserting
output shapes and no NaNs (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import Model


def _batch(cfg, rng, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            rng, (B, cfg.num_patch_tokens, cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    logits, aux = model.forward(params, batch)
    S_total = batch["tokens"].shape[1] + (
        cfg.num_patch_tokens if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one real train step
    from repro.training import OptimizerConfig, init_opt_state, make_train_step
    step = jax.jit(make_train_step(model, OptimizerConfig(warmup_steps=1,
                                                          total_steps=10)))
    params2, opt2, metrics = step(params, init_opt_state(params), batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch, rng):
    cfg = smoke_config(arch)
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(rng)
    B, S, CL = 2, 12, 24
    batch = {k: v for k, v in _batch(cfg, rng, B, S).items() if k != "labels"}
    logits, cache = model.prefill(params, batch, cache_len=CL)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
    tok = tok.astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert not bool(jnp.any(jnp.isnan(logits)))
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
        tok = tok.astype(jnp.int32)
    extra = cfg.num_patch_tokens if cfg.arch_type == "vlm" else 0
    assert int(cache["pos"][0]) == S + extra + 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "grok-1-314b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 1
    if arch == "mamba2-2.7b":
        assert cfg.ssm.d_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm.d_state == 16
    if arch == "gemma3-1b":
        assert cfg.window_size == 1024 and cfg.global_every == 6
