"""Sharding-rule invariants — validated WITHOUT multi-device lowering
(tests keep the single-device constraint; full lowering is covered by
launch/dryrun.py over all 68 combinations)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, \
    pair_is_supported, SKIPPED_PAIRS
from repro.distributed import sharding as sh
from repro.models import Model


class FakeMesh:
    """Just enough of a Mesh for the rule functions (axis sizes + names)."""

    def __init__(self, multi=False):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi
                      else {"data": 16, "model": 16})
        self.axis_names = tuple(self.shape)


def _specs(cfg_name, multi=False):
    import jax.numpy as jnp
    model = Model(get_config(cfg_name), param_dtype=jnp.bfloat16)
    mesh = FakeMesh(multi)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    out = {}
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[pstr] = (sh._spec_for(pstr, leaf.shape, mesh), leaf.shape)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi", [False, True])
def test_every_spec_divides_evenly(arch, multi):
    mesh = FakeMesh(multi)
    for pstr, (spec, shape) in _specs(arch, multi).items():
        for dim, ax in zip(shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (arch, pstr, shape, spec)


def test_ffn_is_tensor_parallel_dense():
    specs = _specs("yi-9b")
    gate = [v for k, v in specs.items() if k.endswith("w_gate")][0]
    assert gate[0][-1] == "model"          # d_ff TP
    down = [v for k, v in specs.items() if k.endswith("w_down")][0]
    assert down[0][-2] == "model"          # row-parallel pair


def test_moe_expert_parallel_when_divisible():
    def _axes(x):
        return (x,) if isinstance(x, str) else x

    llama = _specs("llama4-maverick-400b-a17b")
    gate = [v for k, v in llama.items() if k.endswith("moe/w_gate")][0]
    assert _axes(gate[0][1]) == ("data",)  # 128 experts over 16
    grok = _specs("grok-1-314b")
    gate_g = [v for k, v in grok.items() if k.endswith("moe/w_gate")][0]
    assert gate_g[0][1] is None            # 8 experts can't split 16 ways
    assert _axes(gate_g[0][2]) == ("data",)  # falls back to FSDP on d_model


def test_vocab_parallel_embeddings():
    specs = _specs("gemma3-1b")
    emb = specs["embed"]
    assert emb[0][0] == "model"


def test_skip_matrix_documented():
    assert ("yi-9b", "long_500k") in SKIPPED_PAIRS
    assert pair_is_supported("mamba2-2.7b", "long_500k")
    assert pair_is_supported("gemma3-1b", "long_500k")
    assert not pair_is_supported("whisper-tiny", "long_500k")
    n_supported = sum(pair_is_supported(a, s) for a in ARCH_IDS
                      for s in INPUT_SHAPES)
    assert n_supported == 34
