"""Property-based bucket-math invariants (via tests/_hypothesis_compat.py —
real hypothesis when installed, a deterministic example grid otherwise).

The bucketing layer is what keeps the jitted dispatch's traced-shape space
finite, and its correctness contract is simple enough to state as algebra:
``envelope_bucket`` / ``prefill_bucket`` must be idempotent, monotone,
power-of-two valued and never shrink their input — any violation either
retraces forever (non-idempotent), mis-sorts shapes across buckets
(non-monotone) or slices real rows/columns off a packed operand (shrink).
The dispatch cache key must additionally be insensitive to the scheduler's
urgency reordering of a group (canonical pack order)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.costmodel import GemmShape
from repro.core.dispatch import SuperkernelExecutor, _pow2, _tile_bucket
from repro.core.jit import partition_layers, prefill_bucket
from repro.core.kernelspec import make_op
from repro.core.plancache import PlanCache
from repro.kernels.ops import envelope_bucket


def _is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


# ---------------------------------------------------------------------------
# envelope_bucket
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 16))
def test_envelope_bucket_invariants(x):
    b = envelope_bucket(x)
    assert b >= x                      # never shrinks
    assert b >= 128                    # MXU-tile floor
    assert _is_pow2(b)                 # power-of-two output
    assert envelope_bucket(b) == b     # idempotent


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 15),
       st.integers(min_value=0, max_value=1 << 14))
def test_envelope_bucket_monotone(x, dx):
    assert envelope_bucket(x) <= envelope_bucket(x + dx)


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 12),
       st.sampled_from([8, 16, 64, 128, 256]))
def test_envelope_bucket_respects_minimum(x, minimum):
    b = envelope_bucket(x, minimum=minimum)
    assert b >= minimum and b >= x and _is_pow2(b)
    assert envelope_bucket(b, minimum=minimum) == b


# ---------------------------------------------------------------------------
# prefill_bucket
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 14))
def test_prefill_bucket_invariants(x):
    b = prefill_bucket(x)
    assert b >= x and b >= 8 and _is_pow2(b)
    assert prefill_bucket(b) == b      # idempotent


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 13),
       st.integers(min_value=0, max_value=1 << 12))
def test_prefill_bucket_monotone(x, dx):
    assert prefill_bucket(x) <= prefill_bucket(x + dx)


# ---------------------------------------------------------------------------
# G / m-tile buckets (the dispatch-side power-of-two pads)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 12))
def test_pow2_bucket_invariants(n):
    p = _pow2(n)
    assert p >= n and _is_pow2(p) and _pow2(p) == p


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=300), min_size=1,
                max_size=8),
       st.sampled_from([1, 2, 8, 16]))
def test_tile_bucket_covers_rows(rows, bm):
    tiles = _tile_bucket(rows, bm)
    need = sum((m + bm - 1) // bm for m in rows)
    assert tiles >= need               # the bucket always covers the rows
    assert _is_pow2(tiles)


# ---------------------------------------------------------------------------
# canonical pack order: dispatch cache keys ignore scheduler reordering
# ---------------------------------------------------------------------------

def _rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@settings(max_examples=24, deadline=None)
@given(st.integers(min_value=0, max_value=23))
def test_dispatch_cache_key_pack_order_insensitive(perm_index):
    """Any permutation of a group's ops — the scheduler reorders by urgency
    tick to tick — must resolve to ONE packed-weight entry, with outputs
    restored to call order."""
    import itertools
    problems = [(_rand(2 * i, (4, 128)), _rand(2 * i + 1, (128, 128)))
                for i in range(4)]
    perms = list(itertools.permutations(range(4)))
    perm = perms[perm_index % len(perms)]

    def ops_in(order):
        out = []
        for i in order:
            a, w = problems[i]
            op = make_op(i, "gemv", GemmShape(m=4, n=128, k=128),
                         tag="ffn", seq_index=1)
            op.payload = (a, w, ("w", i))
            out.append(op)
        return out

    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    base = ex.execute(ops_in(range(4)))
    permuted = ex.execute(ops_in(perm))
    assert len(ex.weight_cache) == 1           # one canonical entry
    assert ex.stats.weight_hits == 1           # the permutation HIT it
    for pos, i in enumerate(perm):             # outputs follow CALL order
        np.testing.assert_array_equal(np.asarray(permuted[pos]),
                                      np.asarray(base[i]))


# ---------------------------------------------------------------------------
# partition_layers: the stacked templates' sub-stack partitioner
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.booleans(), min_size=0, max_size=12))
def test_partition_layers_covers_exactly_once_in_order(flags):
    """The spans tile ``range(len(flags))`` exactly once, in order, as
    half-open intervals — a layer dropped from (or repeated in) the scan
    would silently corrupt every tenant of that depth."""
    runs = partition_layers(flags)
    assert all(lo < hi for lo, hi in runs)
    covered = [i for lo, hi in runs for i in range(lo, hi)]
    assert covered == list(range(len(flags)))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=12))
def test_partition_layers_runs_homogeneous_and_maximal(flags):
    """Each span is flag-homogeneous (the flag must be static inside one
    scan body) and maximal (adjacent spans alternate — no needless split
    of a homogeneous stack into extra dispatches)."""
    runs = partition_layers(flags)
    for lo, hi in runs:
        assert len({flags[i] for i in range(lo, hi)}) == 1
    for (a_lo, _), (b_lo, _) in zip(runs, runs[1:]):
        assert flags[a_lo] != flags[b_lo]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.booleans(), min_size=0, max_size=12))
def test_partition_layers_round_trips(flags):
    """The global/local alternation reconstructs exactly from the spans."""
    runs = partition_layers(flags)
    rebuilt = [flags[lo] for lo, hi in runs for _ in range(lo, hi)]
    assert rebuilt == list(flags)
    # homogeneous stacks collapse to ONE span (the O(1)-in-depth case)
    if len(set(flags)) <= 1:
        assert len(runs) == (1 if flags else 0)
