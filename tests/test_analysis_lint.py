"""Tracer-hazard linter: each rule fires on seeded bad code, the shipped
tree lints clean, and the CLI contract (--strict exit code, --json output)
holds."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import Finding, lint_file, lint_paths, main

REPO_SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


def _codes(findings):
    return sorted(f.code for f in findings)


def _lint_source(tmp_path, source, name="probe.py"):
    p = tmp_path / name
    p.write_text(source)
    return lint_file(p)


def test_shipped_tree_lints_clean():
    findings = lint_paths([REPO_SRC])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_th001_jit_closure_over_array_derived(tmp_path):
    fs = _lint_source(tmp_path, """\
import jax

def build(params):
    w = params["w"]
    def inner(x):
        return x @ w
    return jax.jit(inner)
""")
    assert _codes(fs) == ["TH001"]
    assert fs[0].symbol == "inner" and "'w'" in fs[0].message


def test_th001_factory_returned_function_is_rooted(tmp_path):
    fs = _lint_source(tmp_path, """\
import jax

def make_step(params):
    blocks = params["blocks"]
    def step(x):
        return x + blocks
    return step

fn = jax.jit(make_step(P))
""")
    assert _codes(fs) == ["TH001"]


def test_th001_allows_argument_passing_and_module_scope(tmp_path):
    fs = _lint_source(tmp_path, """\
import jax

def make_step(params, cfg):
    def step(p, x):
        return x @ p["w"] * cfg.scale     # params enter as an argument
    return step

fn = jax.jit(make_step(P, C))

W = load()
top = jax.jit(lambda x: x @ W)            # module-level capture: deliberate
""")
    assert fs == []


def test_th002_cache_key_missing_ingredients(tmp_path):
    fs = _lint_source(tmp_path, """\
def broken_program_cache_key(model, params):
    return (id(model), "stacked")
""")
    assert _codes(fs) == ["TH002"]
    assert "dtype" in fs[0].message and ".shape" in fs[0].message


def test_th002_complete_cache_key_passes(tmp_path):
    fs = _lint_source(tmp_path, """\
def good_program_cache_key(model, params, cache):
    return (id(model), str(params["embed"].dtype),
            tuple(cache["k"].shape), ("stacked", True))
""")
    assert fs == []


def test_th003_eager_raw_glue_call(tmp_path):
    fs = _lint_source(tmp_path, """\
from repro.core.jit import _gqa_decode_attend

def eager_path(env):
    return _gqa_decode_attend(env, 0)
""")
    assert _codes(fs) == ["TH003"]
    assert "_gqa_decode_attend" in fs[0].message


def test_th003_allows_jit_rooted_chain_and_defining_module(tmp_path):
    fs = _lint_source(tmp_path, """\
import jax
from repro.models.ssm import decode_core

def core(x):
    return decode_core(x)           # rooted below

fn = jax.jit(core)

def route(x):                       # top-level def: this module defines it
    return x

def local_use(x):
    return route(x)
""")
    assert fs == []


def test_cli_strict_and_json(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def k_cache_key(m):\n    return ()\n")
    assert main([str(bad)]) == 0               # findings alone don't fail
    assert main([str(bad), "--strict"]) == 1
    assert main([str(REPO_SRC), "--strict"]) == 0
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad), "--json"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO_SRC.parent), "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0
    findings = json.loads(out.stdout)
    assert [f["code"] for f in findings] == ["TH002"]
    assert findings[0]["line"] == 1
