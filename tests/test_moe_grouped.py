"""Grouped (GShard-style) MoE dispatch: groups > 1 must match groups == 1
up to capacity semantics, and exactly when capacity is ample."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib


def _setup(E, k, T, d=16, ff=32, cf=8.0, seed=0):
    rng = jax.random.PRNGKey(seed)
    cfg = MoEConfig(num_experts=E, top_k=k, capacity_factor=cf)
    p = moe_lib.init_moe(rng, d, ff, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (T, d))
    return cfg, p, x


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_matches_ungrouped_with_ample_capacity(groups):
    cfg, p, x = _setup(E=4, k=2, T=32)
    y1, aux1 = moe_lib.moe_ffn(p, x, cfg, groups=1)
    yg, auxg = moe_lib.moe_ffn(p, x, cfg, groups=groups)
    np.testing.assert_allclose(yg, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(auxg, aux1, rtol=1e-5, atol=1e-5)


def test_grouped_capacity_is_per_group():
    """With tight capacity, groups localize drops: a token burst routed to
    one expert in one group cannot evict tokens of other groups."""
    cfg, p, x = _setup(E=2, k=1, T=16, cf=1.0)
    y, _ = moe_lib.moe_ffn(p, x, cfg, groups=4)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_indivisible_group_count_falls_back():
    cfg, p, x = _setup(E=2, k=1, T=10)
    # 10 tokens % 4 groups != 0 -> silently uses one group
    y4, _ = moe_lib.moe_ffn(p, x, cfg, groups=4)
    y1, _ = moe_lib.moe_ffn(p, x, cfg, groups=1)
    np.testing.assert_allclose(y4, y1, rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(T=st.sampled_from([8, 16, 32]), E=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2, 4]))
def test_property_grouped_conserves_tokens(T, E, g):
    cfg, p, x = _setup(E=E, k=1, T=T, cf=8.0, seed=3)
    y, aux = moe_lib.moe_ffn(p, x, cfg, groups=g)
    assert y.shape == (T, x.shape[1])
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0
