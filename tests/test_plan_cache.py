"""Plan cache (core/plancache.py): LRU / guard / group invalidation
semantics, template reuse on the serving hot path (bit-identical to
rebuild-per-step, miss only on first step), and regression tests for the
two ROADMAP serving bugs (max_new_tokens=1 over-generation, per-request
eviction identity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.jit import (JitStats, VLIWJit, build_dense_decode_program,
                            build_dense_decode_template,
                            dense_program_cache_key)
from repro.core.plancache import PlanCache, PlanCacheStats
from repro.models import Model
from repro.serving import ServeRequest, ServingEngine, Tenant, two_wave_trace


# ---------------------------------------------------------------------------
# PlanCache unit semantics
# ---------------------------------------------------------------------------

def test_lru_eviction_under_capacity_pressure():
    pc = PlanCache(capacity=2)
    assert pc.get_or_build("a", lambda: 1) == 1
    assert pc.get_or_build("b", lambda: 2) == 2
    assert pc.get_or_build("a", lambda: -1) == 1   # hit refreshes recency
    assert pc.get_or_build("c", lambda: 3) == 3    # evicts b, the LRU entry
    assert pc.stats.evictions == 1
    assert "b" not in pc and "a" in pc and "c" in pc
    assert pc.get_or_build("b", lambda: 4) == 4    # b rebuilds as a miss
    assert pc.stats.hits == 1 and pc.stats.misses == 4


def test_capacity_zero_disables_storage():
    pc = PlanCache(capacity=0)
    assert pc.get_or_build("a", lambda: 1) == 1
    assert pc.get_or_build("a", lambda: 2) == 2    # rebuilt, not cached
    assert len(pc) == 0
    assert pc.stats.hits == 0 and pc.stats.misses == 2


def test_batch_size_change_invalidates_group_entry():
    pc = PlanCache(capacity=8)
    k4, k8 = ("prog", "tenant-a", 4), ("prog", "tenant-a", 8)
    pc.get_or_build(k4, lambda: "plan@4", group="tenant-a")
    pc.get_or_build(k8, lambda: "plan@8", group="tenant-a")
    assert k4 not in pc                 # stale batch-4 plan dropped eagerly
    assert pc.stats.invalidations == 1
    assert k8 in pc


def test_group_invalidation_spares_keys_shared_by_other_groups():
    pc = PlanCache(capacity=8)
    shared = ("prog", "modelX", 4)
    pc.get_or_build(shared, lambda: "p", group="t1")
    pc.get_or_build(shared, lambda: "p", group="t2")
    pc.get_or_build(("prog", "modelX", 8), lambda: "p8", group="t2")
    assert shared in pc                 # t1 still resolves to it
    assert pc.stats.invalidations == 0


def test_identity_guard_invalidates_on_object_swap():
    pc = PlanCache(capacity=8)
    p1, p2 = object(), object()
    assert pc.get_or_build("k", lambda: "v1", guard=p1) == "v1"
    assert pc.get_or_build("k", lambda: "ignored", guard=p1) == "v1"  # hit
    assert pc.get_or_build("k", lambda: "v2", guard=p2) == "v2"  # hot swap
    assert pc.stats.invalidations == 1
    assert pc.stats.hits == 1 and pc.stats.misses == 2
    # the new entry is guarded by the new object
    assert pc.get_or_build("k", lambda: "ignored", guard=p2) == "v2"


def test_tuple_guard_matches_elementwise_by_identity():
    """A tuple guard pins several live objects at once (the engine guards
    templates on (model, params)): swapping either element trips the guard,
    and a fresh-but-identical tuple of the same objects still hits."""
    pc = PlanCache(capacity=8)
    model, params, params2 = object(), object(), object()
    assert pc.get_or_build("k", lambda: "v1", guard=(model, params)) == "v1"
    # a new tuple wrapping the SAME objects is a hit
    assert pc.get_or_build("k", lambda: "x", guard=(model, params)) == "v1"
    assert pc.stats.hits == 1
    # swapping one element (model hot-swap with unchanged params, or the
    # reverse) invalidates — the stale closures are never served
    assert pc.get_or_build("k", lambda: "v2", guard=(model, params2)) == "v2"
    assert pc.stats.invalidations == 1


def test_stats_arithmetic_and_jitstats_merge():
    a = PlanCacheStats(hits=3, misses=1, invalidations=1, evictions=0)
    b = PlanCacheStats(hits=1, misses=2, invalidations=0, evictions=4)
    assert a + b == PlanCacheStats(4, 3, 1, 4)
    assert (a + b) - b == a
    assert (a + b).hit_rate == pytest.approx(4 / 7)
    assert PlanCacheStats().hit_rate == 0.0
    # surfaced through JitStats.merge like every other counter
    ja = JitStats(plan_cache=a.copy(), block_plans=PlanCacheStats(hits=2))
    jb = JitStats(plan_cache=b.copy(),
                  block_plans=PlanCacheStats(evictions=5))
    ja.merge(jb)
    assert ja.plan_cache == a + b
    assert ja.block_plans == PlanCacheStats(hits=2, evictions=5)


# ---------------------------------------------------------------------------
# template bind == fresh build, bit for bit
# ---------------------------------------------------------------------------

def test_template_bind_bit_identical_to_fresh_build(rng):
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    batch = {"tokens": jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=32)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (2, 1), 0,
                             cfg.vocab_size)

    fresh1 = build_dense_decode_program(m, params, tok, cache, stream_id=0)
    VLIWJit(max_group=8).run([fresh1])

    template = build_dense_decode_template(m, params, 2)
    bound1 = template.bind(stream_id=0, tokens=tok, cache=cache)
    VLIWJit(max_group=8).run([bound1])
    np.testing.assert_array_equal(np.asarray(bound1.env["logits"]),
                                  np.asarray(fresh1.env["logits"]))

    # second step from the SAME template: rebind tokens + cache only
    tok2 = jnp.argmax(bound1.env["logits"], axis=-1).astype(jnp.int32)[:, None]
    fresh2 = build_dense_decode_program(m, params, tok2,
                                        fresh1.env["cache"], stream_id=0)
    VLIWJit(max_group=8).run([fresh2])
    bound2 = template.bind(stream_id=0, tokens=tok2,
                           cache=bound1.env["cache"])
    VLIWJit(max_group=8).run([bound2])
    np.testing.assert_array_equal(np.asarray(bound2.env["logits"]),
                                  np.asarray(fresh2.env["logits"]))

    # and both agree with the monolithic decode
    want, _ = m.decode_step(params, tok, cache)
    np.testing.assert_allclose(bound1.env["logits"][:, None, :], want,
                               rtol=2e-4, atol=2e-4)


def test_cache_key_captures_batch_dtype_geometry(rng):
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    c2 = m.init_cache(2, 32)
    assert dense_program_cache_key(m, params, 2, c2) \
        == dense_program_cache_key(m, params, 2, m.init_cache(2, 32))
    assert dense_program_cache_key(m, params, 2, c2) \
        != dense_program_cache_key(m, params, 4, m.init_cache(4, 32))
    assert dense_program_cache_key(m, params, 2, c2) \
        != dense_program_cache_key(m, params, 2, m.init_cache(2, 64))


# ---------------------------------------------------------------------------
# serving hot path: steady-state ticks hit the cache, outputs unchanged
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_models():
    out = {}
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    return out


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def test_engine_cached_bit_identical_with_steady_state_hit_rate(dense_models):
    m1, p1 = dense_models["gemma3-1b"]
    m2, p2 = dense_models["yi-9b"]

    def tenants():
        return [Tenant("a", m1, p1, cache_len=32, max_batch=2),
                Tenant("b", m2, p2, cache_len=32, max_batch=2)]

    steps = 5   # decode steps per request (max_new_tokens - 1)
    trace = two_wave_trace(["a"], ["b"], 1e-5, prompt_len=8,
                           max_new_tokens=steps + 1, slo_s=1.0)
    reps = {}
    for cap in (128, 0):     # cached vs rebuild-per-step baseline
        # analytic prefill: this test pins down the DECODE steady-state
        # miss/hit counts; declared prefill adds its own (per-bucket)
        # template traffic, covered in tests/test_prefill_coalescing.py
        eng = ServingEngine(tenants(), mode="vliw", plan_capacity=cap,
                            declared_prefill=False)
        reps[cap] = eng.run(trace)

    # bit-identical token streams, cached vs uncached
    assert _tokens(reps[128]) == _tokens(reps[0])

    pc = reps[128].jit.plan_cache
    # miss only on each tenant's first step; every steady-state tick hits
    assert pc.misses == 2
    assert pc.hits == 2 * (steps - 1)
    assert pc.hit_rate >= (steps - 1) / steps - 1e-9
    assert pc.invalidations == 0
    un = reps[0].jit.plan_cache
    assert un.hits == 0 and un.misses == un.accesses > 0
    # block plans memoize across dispatches too (same group signatures
    # recur every layer and every step)
    assert reps[128].jit.block_plans.hits > 0


def test_weight_hot_swap_invalidates_and_serves_new_weights(dense_models):
    """Regression (cache-correctness guard): swapping a tenant's params
    mid-run must invalidate its cached template — stale weight closures
    must never be served."""
    m1, p_old = dense_models["gemma3-1b"]
    p_new = Model(m1.cfg, param_dtype=jnp.float32).init(
        jax.random.PRNGKey(77))
    trace1 = [ServeRequest(0, "a", 0.0, 8, 3, 1.0)]
    trace2 = [ServeRequest(1, "a", 0.0, 8, 3, 1.0)]

    eng = ServingEngine([Tenant("a", m1, p_old, cache_len=32, max_batch=2)],
                        mode="vliw")
    eng.run(trace1)
    assert eng.jit.plan_cache.stats.invalidations == 0
    eng.tenants["a"].params = p_new          # weight hot-swap, same model
    rep_swapped = eng.run(trace2)
    assert eng.jit.plan_cache.stats.invalidations >= 1

    fresh = ServingEngine(
        [Tenant("a", m1, p_new, cache_len=32, max_batch=2)], mode="vliw")
    rep_fresh = fresh.run(trace2)
    assert _tokens(rep_swapped) == _tokens(rep_fresh)


# ---------------------------------------------------------------------------
# ROADMAP bugfix regressions
# ---------------------------------------------------------------------------

def test_max_new_tokens_1_retires_at_admission_all_modes(dense_models):
    """Regression: a request whose prefill already produced its only token
    used to join one decode step anyway (slot_remaining==0 slots retired
    only after a decode), emitting an extra token and inflating latency by
    a full step. It must retire at admission, in every mode."""
    m1, p1 = dense_models["gemma3-1b"]

    def tenants():
        return [Tenant("a", m1, p1, cache_len=32, max_batch=2)]

    trace = [ServeRequest(0, "a", 0.0, 8, 1, 1.0),    # single-token request
             ServeRequest(1, "a", 0.0, 8, 4, 1.0)]    # normal batchmate
    probe = ServingEngine(tenants(), mode="vliw")
    prefill_t = probe._prefill_time(m1.cfg, 8)
    toks = {}
    for mode in ("time", "batched", "vliw"):
        eng = ServingEngine(tenants(), mode=mode)
        rep = eng.run(trace)
        r0, r1 = sorted(rep.requests, key=lambda r: r.req_id)
        assert len(r0.tokens_out) == 1    # exactly its one prefill token
        assert len(r1.tokens_out) == 4    # batchmate unaffected
        # retired at admission: latency is prefill only, no decode step
        assert r0.latency <= 2 * prefill_t + 1e-12
        toks[mode] = _tokens(rep)
    assert toks["time"] == toks["batched"] == toks["vliw"]


def test_straggler_next_to_healthy_batchmate_counts_once(dense_models):
    """Regression (per-request eviction identity): a hopeless straggler
    batched next to a healthy request is invisible to (stream, deadline)
    accounting — the program's anchor deadline is the healthy one. With
    request ids plumbed through KernelProgram/KernelOp it counts exactly
    once across all of its steps."""
    m1, p1 = dense_models["gemma3-1b"]
    tenants = [Tenant("a", m1, p1, cache_len=32, max_batch=2)]
    trace = [ServeRequest(0, "a", 0.0, 8, 5, 1e-9),   # already-missed
             ServeRequest(1, "a", 0.0, 8, 5, 10.0)]   # healthy batchmate
    eng = ServingEngine(tenants, mode="vliw")
    rep = eng.run(trace)
    # exactly once for the straggler: not 0 (hidden behind the healthy
    # anchor), not once per step or per GEMM stage
    assert rep.jit.evictions == 1
    # both still complete with correct-length outputs
    assert all(len(r.tokens_out) == 5 for r in rep.requests)
    assert rep.requests[1].met_slo
