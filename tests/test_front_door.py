"""The real-clock SLO front door (ISSUE 10): the serving daemon
(``serve_forever``), tiered admission control at the door, token streaming
tickets, and the serving-metrics bugfix sweep (out-of-order arrival
observations, shed-counts-as-miss attainment, run() no longer mutating its
trace)."""
import math
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.serving import (AdmissionController, ArrivalPredictor, DoorClosed,
                           FrontDoor, MonotonicClock, ServeReport,
                           ServeRequest, ServingEngine, Tenant, TierSpec,
                           VirtualClock, open_loop_trace)


@pytest.fixture(scope="module")
def dense_models():
    out = {}
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    return out


def _tenants(dense_models, max_batch=2, cache_len=32):
    m1, p1 = dense_models["gemma3-1b"]
    m2, p2 = dense_models["yi-9b"]
    return [Tenant("a", m1, p1, cache_len=cache_len, max_batch=max_batch),
            Tenant("b", m2, p2, cache_len=cache_len, max_batch=max_batch)]


def _tokens(rep):
    return {r.req_id: tuple(r.tokens_out or ()) for r in rep.requests}


def _trace(n=4, rate=1e5, max_new=2, slo=1.0):
    return [ServeRequest(i, "ab"[i % 2], i / rate, 8, max_new, slo)
            for i in range(n)]


# ---------------------------------------------------------------------------
# satellite 1: ArrivalPredictor out-of-order observations
# ---------------------------------------------------------------------------

def test_arrival_predictor_folds_out_of_order_observations():
    """Regression: ``observe`` used to silently drop any t < last, so a
    reordered pair (routine with per-device queues + a real clock) lost
    its gap and the EWMA went stale."""
    pred = ArrivalPredictor(alpha=0.5)
    pred.observe("t", 0.0)
    pred.observe("t", 0.2)
    assert pred.gap("t") == pytest.approx(0.2)
    # out-of-order arrival BETWEEN the two seen so far: |0.1 - 0.2| = 0.1
    # is the same inter-arrival sample seen from the other side — it must
    # fold into the EWMA (pre-fix it was dropped and gap stayed 0.2)
    pred.observe("t", 0.1)
    assert pred.gap("t") == pytest.approx(0.5 * 0.1 + 0.5 * 0.2)
    assert pred._last["t"] == pytest.approx(0.2)   # max, not the stale t
    # in-order traffic afterwards keeps folding normally
    pred.observe("t", 0.4)
    assert pred.gap("t") == pytest.approx(0.5 * 0.2 + 0.5 * 0.15)
    assert pred.predict(0.4) < math.inf


def test_arrival_predictor_out_of_order_does_not_regress_last():
    pred = ArrivalPredictor(alpha=0.2)
    pred.observe("t", 1.0)
    pred.observe("t", 0.5)          # late observation, first gap sample
    assert pred.gap("t") == pytest.approx(0.5)
    # predict anchors on the LATEST seen arrival, never the stale one
    assert pred.predict(0.0) == pytest.approx(1.5)


# ---------------------------------------------------------------------------
# satellite 2: shed / unfinished requests count as SLO misses
# ---------------------------------------------------------------------------

def test_report_counts_shed_as_misses():
    """Regression: attainment used to divide met-SLO by FINISHED requests
    only, so anything the door shed (or dropped) silently inflated it."""
    ok = ServeRequest(0, "a", 0.0, 4, 2, slo_s=1.0)
    ok.finish_t, ok.tokens_out = 0.5, [1, 2]
    shed = ServeRequest(1, "a", 0.0, 4, 2, slo_s=1.0, tier=0)
    shed.shed = True
    late = ServeRequest(2, "a", 0.0, 4, 2, slo_s=1.0, tier=1)
    late.finish_t, late.tokens_out = 5.0, [3, 4]
    rep = ServeReport("vliw", [ok, shed, late], modeled_time_s=1.0,
                      wall_time_s=0.0)
    assert rep.shed == 1 and rep.unfinished == 1
    assert rep.slo_attainment == pytest.approx(1.0 / 3.0)   # not 1/2
    assert rep.goodput_rps == pytest.approx(1.0)
    assert rep.p_latency(1.0) == math.inf
    by_tier = rep.tier_attainment()
    assert by_tier[0] == pytest.approx(1.0 / 2.0)
    assert by_tier[1] == 0.0


def test_tier_attainment_groups_degraded_by_original_tier():
    r = ServeRequest(0, "a", 0.0, 4, 2, slo_s=2.0, tier=1)
    r.degraded_from = 0            # arrived tier 0, served at tier 1
    r.finish_t, r.tokens_out = 1.0, [7]
    rep = ServeReport("vliw", [r], modeled_time_s=1.0, wall_time_s=0.0)
    assert rep.tier_attainment(original=True) == {0: 1.0}
    assert rep.tier_attainment(original=False) == {1: 1.0}


# ---------------------------------------------------------------------------
# satellite 3: run() no longer mutates its trace argument
# ---------------------------------------------------------------------------

def test_run_does_not_mutate_trace_and_reruns_bit_identical(dense_models):
    trace = _trace(n=4)
    eng = ServingEngine(_tenants(dense_models), mode="vliw")
    rep1 = eng.run(trace)
    # the caller's request objects are untouched — no deepcopy needed
    assert all(math.isnan(r.finish_t) and r.tokens_out is None
               and not r.shed for r in trace)
    rep2 = eng.run(trace)          # same objects, straight back in
    assert _tokens(rep1) == _tokens(rep2)
    assert all(len(t) == 2 for t in _tokens(rep1).values())
    # and the report's requests are NOT the caller's objects
    assert {id(r) for r in rep1.requests}.isdisjoint(id(r) for r in trace)


# ---------------------------------------------------------------------------
# the admission controller (unit)
# ---------------------------------------------------------------------------

def test_admission_controller_tier_ladder():
    ctl = AdmissionController()
    req = ServeRequest(0, "a", 0.0, 8, 4, slo_s=1.0, tier=0)
    # idle device, cheap request: admit at its own tier
    d = ctl.decide(req, now=0.0, backlog_s=0.0, cost_s=0.1, gap_s=math.inf)
    assert d.action == "admit" and d.tier == 0
    # backlog pushes completion past tier 0's deadline but inside tier 1's
    d = ctl.decide(req, now=0.0, backlog_s=1.5, cost_s=0.1, gap_s=math.inf)
    assert d.action == "degrade" and d.tier == 1
    assert d.slo_s == pytest.approx(2.0)
    # hopeless backlog: shed
    d = ctl.decide(req, now=0.0, backlog_s=50.0, cost_s=0.1, gap_s=math.inf)
    assert d.action == "shed"
    assert ctl.n_shed == 1 and ctl.n_degraded == 1
    # overload margin: rho = cost/gap > 1 tightens the bar
    tight = ctl.decide(req, now=0.0, backlog_s=0.85, cost_s=0.1,
                       gap_s=0.01)
    assert tight.eta_s > 0.95      # margin added on top of backlog + cost


def test_admission_controller_unsheddable_tier_admits_best_effort():
    ctl = AdmissionController(tiers=(TierSpec("gold", 1.0, sheddable=False),),
                              allow_degrade=False)
    req = ServeRequest(0, "a", 0.0, 8, 4, slo_s=0.1, tier=0)
    d = ctl.decide(req, now=0.0, backlog_s=99.0, cost_s=0.1, gap_s=math.inf)
    assert d.action == "admit"     # the miss shows up in attainment instead


# ---------------------------------------------------------------------------
# the FrontDoor object
# ---------------------------------------------------------------------------

def test_front_door_lifecycle_and_guards():
    door = FrontDoor()
    t1 = door.submit(ServeRequest(0, "a", 0.0, 8, 2, 1.0), at=0.5)
    door.submit(ServeRequest(1, "a", 0.0, 8, 2, 1.0))       # live: due now
    with pytest.raises(ValueError, match="duplicate req_id"):
        door.submit(ServeRequest(0, "a", 0.0, 8, 2, 1.0))
    assert not door.finished(0.0)
    out = door.poll(0.0)
    assert [r.req_id for r in out] == [1]
    assert out[0].arrival_t == 0.0          # live submission stamped at poll
    assert door.next_arrival(0.0) == 0.5
    assert door.poll(0.5) == [t1.request]
    assert t1.request.arrival_t == 0.5      # scheduled keeps its stamp
    door.close()
    with pytest.raises(DoorClosed):
        door.submit(ServeRequest(2, "a", 0.0, 8, 2, 1.0))
    assert door.finished(0.5)


def test_front_door_deferred_close():
    door = FrontDoor()
    door.close(at=1.0)
    assert not door.closed(0.5)
    door.submit(ServeRequest(0, "a", 0.0, 8, 2, 1.0), at=2.0)  # pre-close ok
    assert door.closed(1.0)
    with pytest.raises(DoorClosed):
        door.submit(ServeRequest(1, "a", 0.0, 8, 2, 1.0))
    # accepted-but-scheduled submissions still release after closing
    assert door.poll(2.0) != []
    assert door.finished(2.0)


# ---------------------------------------------------------------------------
# tentpole: the daemon loop — idle-wait, flush-on-close, streaming
# ---------------------------------------------------------------------------

def test_daemon_idles_across_gap_and_flushes_on_close(dense_models):
    """The replay stall guard terminates when pending is exhausted; the
    daemon must IDLE through a dead window instead, then serve the late
    arrival and flush cleanly once the door closes — with conservation
    certified over the whole epoch."""
    eng = ServingEngine(_tenants(dense_models), mode="vliw", certify=True)
    door = FrontDoor()
    door.submit(ServeRequest(0, "a", 0.0, 8, 2, 1.0), at=0.0)
    # a gap many times the modeled service time: everything submitted so
    # far completes, queues drain, nothing is live — the replay loop
    # would stop right here
    door.submit(ServeRequest(1, "b", 0.0, 8, 2, 1.0), at=0.5)
    door.close(at=0.6)
    rep = eng.serve_forever(door, clock=VirtualClock())
    assert len(rep.requests) == 2
    assert rep.unfinished == 0 and rep.shed == 0
    assert all(len(r.tokens_out) == 2 for r in rep.requests)
    # the late request was served AFTER the gap, on the virtual clock
    assert rep.requests[1].finish_t > 0.5
    assert rep.modeled_time_s > 0.5
    # conservation over the full daemon epoch (admit/retire balance)
    assert rep.jit.hazard_checks > 0
    assert rep.jit.hazard_violations == 0


def test_daemon_immediate_close_returns_empty_report(dense_models):
    eng = ServingEngine(_tenants(dense_models), mode="vliw")
    door = FrontDoor()
    door.close()
    rep = eng.serve_forever(door, clock=VirtualClock())
    assert rep.requests == [] and rep.unfinished == 0


def test_daemon_streams_tokens_through_tickets(dense_models):
    eng = ServingEngine(_tenants(dense_models), mode="vliw")
    door = FrontDoor()
    seen = []
    tk = door.submit(ServeRequest(0, "a", 0.0, 8, 3, 1.0), at=0.0,
                     on_token=lambda tok, t: seen.append((tok, t)))
    door.close(at=0.01)
    rep = eng.serve_forever(door, clock=VirtualClock())
    (req,) = rep.requests
    assert tk.done and not tk.shed
    # the ticket streamed exactly the tokens the report shows, in order,
    # at nondecreasing virtual times
    assert tk.tokens == req.tokens_out and len(tk.tokens) == 3
    assert [tok for tok, _ in seen] == req.tokens_out
    assert all(t1 <= t2 for (_, t1), (_, t2) in zip(seen, seen[1:]))


def test_daemon_matches_replay_bit_identically(dense_models):
    """A pre-scheduled door driven by the follower VirtualClock must
    reduce exactly to ``run`` on the same trace: same tokens, same finish
    times — the daemon is the same machinery on a different clock."""
    trace = _trace(n=6, rate=1e4, max_new=2)
    eng1 = ServingEngine(_tenants(dense_models), mode="vliw")
    rep_replay = eng1.run(trace)

    eng2 = ServingEngine(_tenants(dense_models), mode="vliw")
    door = FrontDoor()
    for r in trace:
        door.submit(ServeRequest(r.req_id, r.tenant, r.arrival_t,
                                 r.prompt_len, r.max_new_tokens, r.slo_s),
                    at=r.arrival_t)
    door.close(at=max(r.arrival_t for r in trace))
    rep_daemon = eng2.serve_forever(door, clock=VirtualClock())

    assert _tokens(rep_daemon) == _tokens(rep_replay)
    fin_replay = {r.req_id: r.finish_t for r in rep_replay.requests}
    for r in rep_daemon.requests:
        assert r.finish_t == pytest.approx(fin_replay[r.req_id])


def test_daemon_real_clock_live_submissions(dense_models):
    """MonotonicClock smoke: a feeder thread pushes live (unscheduled)
    submissions while the daemon runs on the real clock, then closes the
    door; everything flushes."""
    eng = ServingEngine(_tenants(dense_models), mode="vliw")
    door = FrontDoor()

    def feeder():
        for i in range(3):
            door.submit(ServeRequest(i, "ab"[i % 2], 0.0, 8, 2, 10.0))
        door.close()

    th = threading.Thread(target=feeder)
    th.start()
    rep = eng.serve_forever(door, clock=MonotonicClock())
    th.join()
    assert len(rep.requests) == 3 and rep.unfinished == 0
    # arrivals were stamped on the real clock at release
    assert all(r.arrival_t >= 0.0 for r in rep.requests)
    assert all(r.finish_t >= r.arrival_t for r in rep.requests)


# ---------------------------------------------------------------------------
# tentpole: admission control under overload
# ---------------------------------------------------------------------------

def test_admission_sheds_under_overload_and_keeps_admitted_deadlines(
        dense_models):
    """Open-loop overload (offered load far past capacity): the admitting
    engine sheds at the door, the admitted set keeps hitting its
    deadlines, and attainment/goodput dominate the admit-everything
    ablation — with bit-identical tokens on the jointly-finished set."""
    eng_ctl = ServingEngine(_tenants(dense_models), mode="vliw",
                            admission_control=True)
    cost = eng_ctl._request_cost_s(
        eng_ctl.tenants["a"], ServeRequest(0, "a", 0.0, 8, 2, 1.0))
    # ~8x the modeled per-request service rate, tiered SLOs scaled to the
    # cost model so the knee is real but tier deadlines are meetable
    trace = open_loop_trace(
        ["a", "b"], rate_hz=8.0 / cost, n=36, shape="poisson",
        tier_slo_s=(4 * cost, 8 * cost, 12 * cost), prompt_len=8,
        max_new_tokens=2, seed=7)
    rep_ctl = eng_ctl.run(trace)

    eng_all = ServingEngine(_tenants(dense_models), mode="vliw")
    rep_all = eng_all.run(trace)

    assert rep_ctl.shed > 0
    assert eng_ctl.admission.n_shed == rep_ctl.shed
    # shed requests count as misses, never as successes
    assert all(not r.met_slo for r in rep_ctl.requests if r.shed)
    assert rep_ctl.slo_attainment > rep_all.slo_attainment
    assert rep_ctl.goodput_rps > rep_all.goodput_rps
    # the ADMITTED requests kept their (possibly degraded) promises far
    # better than the drowning admit-everything queue
    admitted = [r for r in rep_ctl.requests if not r.shed]
    att_admitted = sum(r.met_slo for r in admitted) / len(admitted)
    assert att_admitted > rep_all.slo_attainment
    # token bit-identity on the jointly finished set: admission changes
    # WHO runs, never the math of what runs
    toks_all = _tokens(rep_all)
    for r in rep_ctl.requests:
        if r.tokens_out is not None and toks_all.get(r.req_id):
            assert tuple(r.tokens_out) == toks_all[r.req_id]
