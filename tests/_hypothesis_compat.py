"""Hypothesis import shim for the property tests.

The real ``hypothesis`` package is an optional dev dependency
(requirements-dev.txt). When it is absent — e.g. in the minimal container —
this module provides a tiny deterministic fallback: each ``@given`` test runs
over a fixed grid of representative examples (strategy bounds, midpoints and
sampled values, zipped by index), so the suite still collects and exercises
the properties instead of erroring at import time.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import itertools

    class _Strategy:
        """A strategy reduced to a fixed list of representative examples."""

        def __init__(self, examples):
            self.examples = list(examples)
            assert self.examples, "strategy with no examples"

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Strategy(sorted({min_value, mid, max_value}))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(seq)

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            mid = 0.5 * (min_value + max_value)
            return _Strategy(sorted({min_value, mid, max_value}))

        @staticmethod
        def tuples(*elems):
            n = max(len(e.examples) for e in elems)
            return _Strategy(tuple(e.examples[i % len(e.examples)]
                                   for e in elems) for i in range(n))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            sizes = sorted({min_size, min(max(min_size, 3), max_size),
                            max_size})
            out = []
            for size in sizes:
                cyc = itertools.cycle(elem.examples)
                out.append([next(cyc) for _ in range(size)])
            return _Strategy(out)

    st = _St()

    def settings(*_args, **_kwargs):
        """No-op stand-in for hypothesis.settings."""
        def deco(fn):
            return fn
        return deco

    def given(*garg_strats, **gkw_strats):
        """Run the test once per example row (examples zipped by index).

        Like real hypothesis, positional strategies bind the test's
        RIGHTMOST parameters, so leading pytest fixtures keep working."""
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            pos_named = names[len(names) - len(garg_strats):] \
                if garg_strats else []
            strats = dict(zip(pos_named, garg_strats))
            strats.update(gkw_strats)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = max(len(s.examples) for s in strats.values())
                for i in range(n):
                    ex = {name: s.examples[i % len(s.examples)]
                          for name, s in strats.items()}
                    fn(*args, **ex, **kwargs)

            # hide the strategy-bound parameters from pytest's fixture
            # resolution (real hypothesis does the same)
            params = [p for name, p in sig.parameters.items()
                      if name not in strats]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco
