"""The jitted dispatch fast path (core/dispatch.py): packed-weight cache
bit-identity + hot-swap invalidation, envelope-bucket math, retrace-free
steady-state ticks, aspect-from-bm classification, and the arrival-
prediction EWMA."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import GemmShape, make_op, op_aspect
from repro.core.dispatch import SuperkernelExecutor, trace_count
from repro.core.jit import (VLIWJit, build_dense_decode_program,
                            build_dense_decode_template)
from repro.core.plancache import PlanCache
from repro.kernels.ops import (coalesced_matvec, envelope_bucket,
                               execute_superkernel)
from repro.models import Model
from repro.serving import (ArrivalPredictor, ServingEngine, Tenant,
                           poisson_arrivals, two_wave_trace)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _ops_for(problems, wkeys):
    ops = []
    for i, ((a, w), key) in enumerate(zip(problems, wkeys)):
        op = make_op(i, "gemv", GemmShape(m=int(a.shape[0]),
                                          n=int(w.shape[1]),
                                          k=int(w.shape[0])))
        op.payload = (a, w, key)
        ops.append(op)
    return ops


# ---------------------------------------------------------------------------
# envelope-bucket math
# ---------------------------------------------------------------------------

def test_envelope_bucket_math():
    # floor at the 128-lane tile, then powers of two
    assert envelope_bucket(1) == 128
    assert envelope_bucket(128) == 128
    assert envelope_bucket(129) == 256
    assert envelope_bucket(256) == 256
    assert envelope_bucket(257) == 512
    assert envelope_bucket(513) == 1024
    assert envelope_bucket(5, minimum=8) == 8
    for x in range(1, 700):
        b = envelope_bucket(x)
        assert b >= max(x, 128) and (b & (b - 1)) == 0   # covering po2
        assert b % 128 == 0                              # MXU-aligned


# ---------------------------------------------------------------------------
# bit-identity vs the eager reference path
# ---------------------------------------------------------------------------

def test_executor_bit_identical_to_eager_grouped():
    """Power-of-two dims: bucketing is exact padding-with-zeros, so the
    jitted fast path must be BIT-identical to the eager reference."""
    probs = [(_rand(2 * i, (4, 128)), _rand(2 * i + 1, (128, 256)))
             for i in range(3)]
    ops = _ops_for(probs, [("w", i) for i in range(3)])
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    fast = ex.execute(ops)
    ref = execute_superkernel(probs, bm=8)
    for f, r in zip(fast, ref):
        assert f.shape == r.shape
        np.testing.assert_array_equal(np.asarray(f), np.asarray(r))


def test_executor_matches_eager_ragged_dims():
    """Non-power-of-two dims: the bucketed envelope (512) differs from the
    eager exact envelope (384), so only numerical closeness is guaranteed
    (zero padding is exact per accumulation step; the contraction length
    differs)."""
    probs = [(_rand(0, (5, 300)), _rand(1, (300, 200))),
             (_rand(2, (11, 260)), _rand(3, (260, 190)))]
    ops = _ops_for(probs, [("w", 0), ("w", 1)])
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    fast = ex.execute(ops)
    ref = execute_superkernel(probs, bm=8)
    for f, r in zip(fast, ref):
        assert f.shape == r.shape
        np.testing.assert_allclose(np.asarray(f), np.asarray(r),
                                   rtol=1e-4, atol=1e-5)


def test_executor_shared_operand_bit_identical():
    w = _rand(9, (128, 256))
    probs = [(_rand(i, (4, 128)), w) for i in range(4)]
    ops = _ops_for(probs, [("shared-w",)] * 4)
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    fast = ex.execute(ops, shared_operand=True)
    ref = execute_superkernel(probs, bm=8, shared_operand=True)
    for f, r in zip(fast, ref):
        assert f.shape == r.shape
        np.testing.assert_array_equal(np.asarray(f), np.asarray(r))


def test_executor_matvec_matches_eager():
    xs = [_rand(i, (128,)) for i in range(4)]
    ws_shared = [_rand(99, (128, 256))] * 4
    ws_distinct = [_rand(50 + i, (128, 256)) for i in range(4)]
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    for ws in (ws_shared, ws_distinct):
        fast = ex.matvec(xs, ws)
        ref = coalesced_matvec(xs, ws)
        for f, r in zip(fast, ref):
            assert f.shape == r.shape
            np.testing.assert_array_equal(np.asarray(f), np.asarray(r))
    # the shared regime routed through the shared-operand GEMM fast path
    assert ex.stats.dispatches == 2


def test_executor_disabled_is_the_eager_path():
    probs = [(_rand(0, (4, 128)), _rand(1, (128, 128)))]
    ops = _ops_for(probs, [("w", 0)])
    ex = SuperkernelExecutor(PlanCache(32), bm=8, enabled=False)
    fast = ex.execute(ops)
    ref = execute_superkernel(probs, bm=8)
    np.testing.assert_array_equal(np.asarray(fast[0]), np.asarray(ref[0]))
    assert ex.stats.dispatches == 0       # ablation path counts nothing


# ---------------------------------------------------------------------------
# the persistent packed-weight cache
# ---------------------------------------------------------------------------

def test_weight_pack_cache_hits_and_bytes_not_copied():
    probs = [(_rand(2 * i, (4, 128)), _rand(2 * i + 1, (128, 256)))
             for i in range(3)]
    ops = _ops_for(probs, [("w", i) for i in range(3)])
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    first = ex.execute(ops)
    assert ex.stats.weight_misses == 1 and ex.stats.weight_hits == 0
    assert ex.stats.bytes_not_copied == 0
    steps = 5
    for _ in range(steps):
        again = ex.execute(ops)
    assert ex.stats.weight_hits == steps          # every re-dispatch hits
    assert ex.stats.weight_hit_rate >= steps / (steps + 1)
    # hits count the packed operand bytes NOT re-staged: G_pad × K × N fp32
    assert ex.stats.bytes_not_copied == steps * 4 * 128 * 256 * 4
    for f, r in zip(again, first):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(r))


def test_weight_hot_swap_invalidates_and_recomputes():
    """Same weight keys, NEW weight arrays (a hot-swap): the identity guard
    must trip — counted as an invalidation — and the outputs must reflect
    the new weights, never the cached stale pack."""
    a = _rand(0, (4, 128))
    old_w, new_w = _rand(1, (128, 128)), _rand(2, (128, 128))
    keys = [("tenant", 0, "ffn")]
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    ex.execute(_ops_for([(a, old_w)], keys))
    ex.execute(_ops_for([(a, old_w)], keys))
    assert ex.stats.weight_hits == 1
    swapped = ex.execute(_ops_for([(a, new_w)], keys))
    assert ex.stats.weight_invalidations == 1
    assert ex.stats.weight_hits == 1              # swap was NOT a hit
    ref = execute_superkernel([(a, new_w)], bm=8)
    np.testing.assert_array_equal(np.asarray(swapped[0]),
                                  np.asarray(ref[0]))


def test_key_changing_hot_swap_drops_stale_pack():
    """The serving hot-swap path replaces the params tree, so every weight
    key embeds a NEW id(params) — a different cache key. The dispatch
    slot's params-free group tag must eagerly drop the superseded packed
    entry (which pins the old weight arrays) instead of letting stale
    generations pile up until LRU pressure."""
    a = _rand(0, (4, 128))
    old_w, new_w = _rand(1, (128, 128)), _rand(2, (128, 128))
    cache = PlanCache(32)
    ex = SuperkernelExecutor(cache, bm=8)

    def ops_with(w, pid):
        # same stream/tag/seq (same logical slot), pid-bearing weight key
        op = make_op(0, "gemv", GemmShape(m=4, n=128, k=128), tag="ffn",
                     seq_index=3)
        op.payload = (a, w, ("arch", pid, 3, "ffn"))
        return [op]

    ex.execute(ops_with(old_w, 111))
    assert len(cache) == 1
    swapped = ex.execute(ops_with(new_w, 222))   # hot-swap: new key
    assert len(cache) == 1                       # stale pack dropped, not 2
    assert ex.stats.weight_invalidations == 1
    ref = execute_superkernel([(a, new_w)], bm=8)
    np.testing.assert_array_equal(np.asarray(swapped[0]),
                                  np.asarray(ref[0]))


def test_dispatch_order_insensitive_weight_cache():
    """The scheduler reorders a group's ops by urgency tick to tick; the
    packed-weight key and group tag must be canonical so an order flip is
    a HIT on the same entry, with outputs restored to call order."""
    pa = (_rand(0, (4, 128)), _rand(1, (128, 128)))
    pb = (_rand(2, (4, 128)), _rand(3, (128, 128)))
    cache = PlanCache(32)
    ex = SuperkernelExecutor(cache, bm=8)

    def ops_in(order):
        out = []
        for (a, w), sid, key in order:
            op = make_op(sid, "gemv", GemmShape(m=4, n=128, k=128),
                         tag="ffn", seq_index=1)
            op.payload = (a, w, key)
            out.append(op)
        return out

    fwd = ex.execute(ops_in([(pa, 0, ("w", 0)), (pb, 1, ("w", 1))]))
    rev = ex.execute(ops_in([(pb, 1, ("w", 1)), (pa, 0, ("w", 0))]))
    assert len(cache) == 1                       # one entry, both orders
    assert ex.stats.weight_hits == 1             # the flip HIT it
    # outputs follow CALL order: rev[0] is B's result, rev[1] is A's
    np.testing.assert_array_equal(np.asarray(rev[0]), np.asarray(fwd[1]))
    np.testing.assert_array_equal(np.asarray(rev[1]), np.asarray(fwd[0]))


def test_group_map_pruned_with_entries():
    """_group_key mappings must die with their entries — the dispatch path
    feeds one tuple per group composition, which would otherwise grow
    forever over a long serving session."""
    cache = PlanCache(capacity=2)
    for i in range(6):
        cache.get_or_build(("k", i), lambda i=i: i, group=("slot", i))
    assert len(cache) == 2
    assert len(cache._group_key) <= 2            # evicted keys took their
    assert cache.stats.evictions == 4            # mappings with them


def test_weight_cache_byte_budget_bounds_memory():
    """Entries are full packed weight copies, so the cache must bound
    BYTES, not just entry count: inserting past the byte budget evicts
    LRU entries (keeping at least the newest)."""
    budget = 3 * 128 * 128 * 4            # room for ~3 stacked [1,128,128]
    cache = PlanCache(capacity=64, byte_capacity=budget)
    ex = SuperkernelExecutor(cache, bm=8)
    a = _rand(0, (4, 128))
    for i in range(6):                    # 6 DISTINCT dispatch slots
        w = _rand(10 + i, (128, 128))
        op = make_op(i, "gemv", GemmShape(m=4, n=128, k=128), tag=f"s{i}")
        op.payload = (a, w, ("w", i))
        ex.execute([op])
    assert cache.bytes <= budget
    assert cache.stats.evictions >= 3     # LRU reclaimed the overflow
    assert len(cache) >= 1                # newest entry always retained
    probs = [(_rand(0, (4, 128)), _rand(1, (128, 128)))]
    ex = SuperkernelExecutor(PlanCache(0), bm=8)
    for _ in range(3):
        ex.execute(_ops_for(probs, [("w", 0)]))
    assert ex.stats.weight_hits == 0 and ex.stats.weight_misses == 3


# ---------------------------------------------------------------------------
# retrace-free steady state
# ---------------------------------------------------------------------------

def test_executor_zero_retraces_after_warmup():
    probs = [(_rand(2 * i, (4, 128)), _rand(2 * i + 1, (128, 256)))
             for i in range(3)]
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    ex.execute(_ops_for(probs, [("w", i) for i in range(3)]))
    warm = ex.stats.retraces
    for _ in range(4):
        ex.execute(_ops_for(probs, [("w", i) for i in range(3)]))
    assert ex.stats.retraces == warm      # steady state: zero new traces


def test_group_churn_stays_inside_the_buckets():
    """Group-size churn within one (G, m-tile) bucket must not retrace:
    5..8 problems of the same shape all bucket to G_pad=8 / 8 m-tiles."""
    probs = [(_rand(2 * i, (4, 128)), _rand(2 * i + 1, (128, 256)))
             for i in range(8)]
    wkeys = [("w", i) for i in range(8)]
    ex = SuperkernelExecutor(PlanCache(32), bm=8)
    ex.execute(_ops_for(probs, wkeys))    # warm the g=8 bucket
    warm = ex.stats.retraces
    for g in (7, 6, 5, 8, 6):
        ex.execute(_ops_for(probs[:g], wkeys[:g]))
    assert ex.stats.retraces == warm


def test_steady_state_ticks_zero_retraces(rng):
    """The acceptance assertion at the JIT level: after a warmup run, a
    second session over rebound programs of the same shapes must not trace
    a single jitted dispatch body (trace-counter delta == 0), and every
    weight pack must be served from the persistent cache."""
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    batch = {"tokens": jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=32)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (2, 1), 0,
                             cfg.vocab_size)

    jit = VLIWJit(max_group=8)
    # the serving hot path: the template is compiled ONCE (plan cache) and
    # each steady-state tick only rebinds the per-step env — which is what
    # keeps the weight-array identities (and so the packed-weight guard)
    # stable across ticks
    template = build_dense_decode_template(m, params, 2)

    def progs():
        return [template.bind(stream_id=i, tokens=tok, cache=cache)
                for i in range(3)]

    warm_stats = jit.run(progs())          # warmup: traces + weight packs
    assert warm_stats.dispatch.weight_misses > 0
    before = trace_count()
    steady = jit.run(progs())
    assert trace_count() == before         # not one retrace in steady state
    assert steady.dispatch.retraces == 0
    assert steady.dispatch.weight_misses == 0
    assert steady.dispatch.weight_hit_rate == 1.0
    assert steady.dispatch.bytes_not_copied > 0


# ---------------------------------------------------------------------------
# aspect classification derives from the JIT's m-tile
# ---------------------------------------------------------------------------

def test_op_aspect_boundary():
    assert op_aspect(1) == "gemv" and op_aspect(8) == "gemv"
    assert op_aspect(9) == "gemm"
    assert op_aspect(9, max_gemv_rows=16) == "gemv"
    assert op_aspect(17, max_gemv_rows=16) == "gemm"


def test_push_op_aspect_from_jit_bm(rng):
    """_push_op must classify gemv-vs-gemm from the JIT's configured bm,
    not a hard-coded 8 (regression: batch-4 rows were 'gemv' under ANY
    tile size)."""
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    batch = {"tokens": jax.random.randint(rng, (4, 12), 0, cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=32)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (4, 1), 0,
                             cfg.vocab_size)
    kinds = {}
    for bm in (2, 8):
        session = VLIWJit(max_group=8, bm=bm).session()
        session.admit(build_dense_decode_program(m, params, tok, cache,
                                                 stream_id=0))
        (op,) = session.sched.ready
        kinds[bm] = op.kind
    assert kinds == {2: "gemm", 8: "gemv"}   # 4 rows vs the tile boundary


# ---------------------------------------------------------------------------
# engine integration: token identity + stats plumbing
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_pair():
    out = {}
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    return out


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def _two_tenants(dense_pair):
    m1, p1 = dense_pair["gemma3-1b"]
    m2, p2 = dense_pair["yi-9b"]
    return [Tenant("a", m1, p1, cache_len=32, max_batch=2),
            Tenant("b", m2, p2, cache_len=32, max_batch=2)]


def test_engine_cached_dispatch_token_identity(dense_pair):
    """The serving acceptance: the jitted cached dispatch path must emit
    bit-identical greedy tokens to the eager reference path, with the
    DispatchStats plumbed through JitStats."""
    trace = two_wave_trace(["a"], ["b"], 1e-5, prompt_len=8,
                           max_new_tokens=4, slo_s=1.0)
    reps = {}
    for name, enabled in (("eager", False), ("jitted", True)):
        eng = ServingEngine(_two_tenants(dense_pair), mode="vliw")
        eng.jit.executor.enabled = enabled
        reps[name] = eng.run(trace)
    assert _tokens(reps["eager"]) == _tokens(reps["jitted"])
    d = reps["jitted"].jit.dispatch
    assert d.dispatches == reps["jitted"].jit.superkernels
    assert d.weight_hits + d.weight_misses == d.dispatches
    assert d.weight_hits > 0 and d.bytes_not_copied > 0
    # the eager ablation records nothing through the fast path
    assert reps["eager"].jit.dispatch.dispatches == 0


def test_engine_predict_arrivals_flag(dense_pair):
    """predict_arrivals=True blinds the scheduler to the replay trace and
    feeds the EWMA instead — scheduling hints change, tokens must not."""
    trace = two_wave_trace(["a"], ["b"], 1e-5, prompt_len=8,
                           max_new_tokens=4, slo_s=1.0)
    reps = {}
    for name, kw in (("replay", {}), ("ewma", dict(predict_arrivals=True))):
        eng = ServingEngine(_two_tenants(dense_pair), mode="vliw", **kw)
        assert eng.predict_arrivals == bool(kw)   # defaults to trace-driven
        reps[name] = eng.run(trace)
    assert _tokens(reps["replay"]) == _tokens(reps["ewma"])


# ---------------------------------------------------------------------------
# the arrival-prediction EWMA
# ---------------------------------------------------------------------------

def test_ewma_converges_on_poisson_trace():
    rate = 50.0
    rng = np.random.default_rng(7)
    pred = ArrivalPredictor(alpha=0.05)
    last = 0.0
    for t in poisson_arrivals(rate, 800, rng):
        pred.observe("t1", t)
        last = t
    # the EWMA gap estimate converges to the mean inter-arrival 1/rate
    assert pred.gap("t1") == pytest.approx(1.0 / rate, rel=0.35)
    # prediction is a strictly future instant once a gap is known
    assert pred.predict(last) > last
    # an overdue estimate restarts the clock (memoryless) instead of
    # handing the scheduler a stale past instant
    far = last + 100.0
    assert pred.predict(far) == pytest.approx(far + pred.gap("t1"))


def test_ewma_unseen_tenants_never_wait():
    pred = ArrivalPredictor()
    assert pred.predict(0.0) == math.inf
    pred.observe("t1", 1.0)               # one arrival: no gap yet
    assert pred.predict(2.0) == math.inf
    assert pred.gap("t1") == math.inf


def test_ewma_reset_survives_clock_restart():
    """A reused engine's runs each restart the virtual clock at 0; without
    a reset the stored last-arrival (end of run 1) sits ahead of every new
    arrival and observe() silently drops all of run 2's gaps."""
    pred = ArrivalPredictor(alpha=0.5)
    for t in (1.0, 2.0, 3.0):
        pred.observe("t1", t)
    assert pred.gap("t1") == pytest.approx(1.0)
    pred.reset()
    assert pred.predict(0.0) == math.inf
    for t in (0.1, 0.3):                  # the new epoch IS observed
        pred.observe("t1", t)
    assert pred.gap("t1") == pytest.approx(0.2)


def test_engine_run_resets_predictor(dense_pair):
    trace = two_wave_trace(["a"], ["b"], 1e-5, prompt_len=8,
                           max_new_tokens=2, slo_s=1.0)
    eng = ServingEngine(_two_tenants(dense_pair), mode="vliw",
                        predict_arrivals=True)
    eng.run(trace)
    eng.run(trace)                        # second epoch on the same engine
    # the predictor reflects the SECOND run's trace, not a poisoned merge
    assert all(t <= 1e-5 for t in eng._arrival_pred._last.values())


# ---------------------------------------------------------------------------
# tied-embedding weight identity across templates
# ---------------------------------------------------------------------------

def _unembed_weight(template):
    from repro.core.jit import GemmStage
    stage = [s for s in template.stages
             if isinstance(s, GemmStage) and s.tag == "unembed"][-1]
    return stage.weight_fn()


def test_tied_unembed_identity_across_templates(rng):
    """Every template of one (model, params) — decode at any batch,
    prefill at any bucket — must hand out the SAME transposed unembed
    array: a per-template transpose makes batch alternation look like a
    weight hot-swap to the packed-weight guard and repacks the model's
    largest matrix every flip."""
    from repro.core.jit import build_dense_prefill_template
    cfg = smoke_config("gemma3-1b")
    assert cfg.tie_embeddings
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    w2 = _unembed_weight(build_dense_decode_template(m, params, 2))
    w4 = _unembed_weight(build_dense_decode_template(m, params, 4))
    wp = _unembed_weight(build_dense_prefill_template(m, params, 16))
    assert w2 is w4 and w2 is wp
    # a hot-swap (new params) must NOT share the transpose
    params2 = m.init(jax.random.fold_in(rng, 1))
    assert _unembed_weight(build_dense_decode_template(m, params2, 2)) \
        is not w2
