"""Per-kernel interpret-mode validation against the ref.py oracles:
shape/dtype sweeps + hypothesis property tests (assignment requirement (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import (coalesced_gemm, coalesced_gemv, coalesced_matvec,
                           execute_superkernel, flash_attention,
                           pack_problems, windowed_attention)
from repro.kernels import ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# coalesced_gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 8e-2)])
@pytest.mark.parametrize("problems", [
    [(32, 128, 128)],
    [(100, 256, 384), (64, 200, 384), (17, 256, 300)],
    [(8, 128, 128)] * 5,
    [(130, 130, 130), (1, 512, 256)],
])
def test_coalesced_gemm_matches_ref(problems, dtype, tol):
    probs = []
    for i, (m, k, n) in enumerate(problems):
        probs.append((_rand(2 * i, (m, k), dtype), _rand(2 * i + 1, (k, n), dtype)))
    outs = execute_superkernel(probs, bm=32, bn=128, bk=128)
    for (a, b), o in zip(probs, outs):
        want = (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(dtype)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol * 8)


def test_coalesced_gemm_kernel_direct():
    a = _rand(0, (64, 32), jnp.float32)
    b = _rand(1, (3, 32, 128), jnp.float32)
    gids = jnp.asarray([0, 1, 1, 2], jnp.int32)
    out = coalesced_gemm(a, b, gids, bm=16, bn=128, bk=32)
    want = ref.coalesced_gemm_ref(a, b, gids, bm=16)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=20)
@given(
    g=st.integers(1, 4),
    mt=st.integers(1, 3),
    k=st.sampled_from([64, 128, 256]),
    n=st.sampled_from([128, 256]),
)
def test_coalesced_gemm_property(g, mt, k, n):
    """Property: grouped kernel == per-tile einsum oracle for random
    group-id assignments."""
    bm = 16
    M = mt * g * bm
    a = _rand(g * 7 + mt, (M, k), jnp.float32)
    b = _rand(g * 11 + n, (g, k, n), jnp.float32)
    gids = jnp.asarray(np.random.RandomState(g + mt).randint(0, g, M // bm),
                       jnp.int32)
    out = coalesced_gemm(a, b, gids, bm=bm, bn=min(128, n), bk=min(128, k))
    want = ref.coalesced_gemm_ref(a, b, gids, bm=bm)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# coalesced_gemv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G,K,N", [(1, 128, 128), (3, 256, 384),
                                   (8, 512, 128)])
def test_coalesced_gemv_matches_ref(G, K, N):
    x = _rand(0, (G, K), jnp.float32)
    w = _rand(1, (G, K, N), jnp.float32)
    out = coalesced_gemv(x, w, bn=128, bk=128)
    np.testing.assert_allclose(out, ref.coalesced_gemv_ref(x, w),
                               rtol=2e-4, atol=2e-4)


def test_coalesced_matvec_shared_vs_distinct():
    w = _rand(5, (192, 320), jnp.float32)
    xs = [_rand(10 + i, (192,), jnp.float32) for i in range(4)]
    shared = coalesced_matvec(xs, [w] * 4)
    distinct = coalesced_matvec(xs, [w + 0 for _ in range(4)])
    for x, s, d in zip(xs, shared, distinct):
        want = x @ w
        np.testing.assert_allclose(s, want, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(d, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(window, causal, dtype, tol):
    if window and not causal:
        pytest.skip("window implies causal in our serving paths")
    B, H, S, D = 2, 3, 256, 64
    q = _rand(0, (B, H, S, D), dtype)
    k = _rand(1, (B, H, S, D), dtype)
    v = _rand(2, (B, H, S, D), dtype)
    out = windowed_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@settings(deadline=None, max_examples=10)
@given(bq=st.sampled_from([32, 64, 128]), bkv=st.sampled_from([32, 64, 128]),
       window=st.sampled_from([0, 32, 96]))
def test_flash_attention_block_invariance(bq, bkv, window):
    """Property: result is independent of the BlockSpec tiling."""
    B, H, S, D = 1, 2, 128, 32
    q = _rand(3, (B * H, S, D), jnp.float32)
    k = _rand(4, (B * H, S, D), jnp.float32)
    v = _rand(5, (B * H, S, D), jnp.float32)
    out = flash_attention(q, k, v, bq=bq, bkv=bkv, causal=True, window=window)
    base = flash_attention(q, k, v, bq=S, bkv=S, causal=True, window=window)
    np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5)


def test_pack_problems_roundtrip():
    probs = [(_rand(0, (17, 100), jnp.float32), _rand(1, (100, 200), jnp.float32)),
             (_rand(2, (33, 256), jnp.float32), _rand(3, (256, 130), jnp.float32))]
    packed = pack_problems(probs, bm=32)
    assert packed.a_packed.shape[0] % 32 == 0
    assert packed.a_packed.shape[1] % 128 == 0
    assert packed.b_stacked.shape[0] == 2
    # group ids cover each problem's tiles contiguously
    assert packed.group_ids.tolist() == [0] + [1, 1]
