"""Training substrate: optimizer math, schedule, data pipeline determinism,
checkpoint round-trip, loss decreases end-to-end."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.training import (DataConfig, OptimizerConfig, SyntheticLM,
                            adamw_update, checkpoint_step, init_opt_state,
                            lr_at, restore_checkpoint, save_checkpoint, train)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(1e-4, rel=0.01)
    mid = float(lr_at(cfg, jnp.asarray(55)))
    assert 1e-4 < mid < 1e-3


def test_adamw_step_moves_params_and_clips(rng):
    params = {"w": jax.random.normal(rng, (8, 8)),
              "b": jnp.zeros((8,))}
    grads = {"w": 100.0 * jnp.ones((8, 8)), "b": jnp.ones((8,))}
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, clip_norm=1.0)
    state = init_opt_state(params)
    new_params, new_state, m = adamw_update(cfg, params, grads, state)
    assert float(m["grad_norm"]) > 1.0     # raw norm reported
    assert int(new_state.step) == 1
    assert not np.allclose(np.asarray(new_params["w"]),
                           np.asarray(params["w"]))


def test_data_pipeline_deterministic_and_shaped():
    cfg = smoke_config("yi-9b")
    a = next(iter(SyntheticLM(cfg, DataConfig(batch_size=3, seq_len=32,
                                              seed=5))))
    b = next(iter(SyntheticLM(cfg, DataConfig(batch_size=3, seq_len=32,
                                              seed=5))))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (3, 32)
    assert (a["tokens"] >= 0).all() and (a["tokens"] < cfg.vocab_size).all()
    # labels are next-token shifted views of the same stream
    assert a["labels"].shape == (3, 32)


def test_vlm_and_audio_batches_have_modality_stubs():
    for arch, key in (("internvl2-2b", "patch_embeds"),
                      ("whisper-tiny", "frames")):
        cfg = smoke_config(arch)
        b = next(iter(SyntheticLM(cfg, DataConfig(batch_size=2, seq_len=16))))
        assert key in b and b[key].shape[0] == 2


def test_train_loss_decreases(rng):
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    res = train(m, SyntheticLM(cfg, DataConfig(batch_size=4, seq_len=64)),
                steps=40, log_every=0,
                opt_cfg=OptimizerConfig(lr=1e-3, warmup_steps=5,
                                        total_steps=40))
    l = res["losses"]
    assert sum(l[-5:]) / 5 < sum(l[:5]) / 5 - 0.05


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = smoke_config("hymba-1.5b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    opt = init_opt_state(params)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, {"params": params, "opt": opt}, step=7)
    ref = {"params": jax.eval_shape(lambda: params),
           "opt": jax.eval_shape(lambda: opt)}
    restored = restore_checkpoint(path, ref)
    assert checkpoint_step(path) == 7
    flat_a = jax.tree_util.tree_leaves(restored["params"])
    flat_b = jax.tree_util.tree_leaves(params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
