"""Banded sliding-window attention (§Perf W1) must equal the masked-full
formulation on both the chunked prefill path and the decode path (including
mixed per-row positions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


@pytest.mark.parametrize("is_global", [False, True])
def test_chunked_banded_matches_masked(is_global, rng, monkeypatch):
    B, S, H, Hkv, hd = 1, 4096, 2, 1, 16
    window = 256
    d = H * hd
    params = A.init_attention(rng, d, H, Hkv, hd, jnp.float32)
    x = 0.3 * jax.random.normal(rng, (B, S, d))
    kw = dict(num_heads=H, num_kv_heads=Hkv, head_dim=hd, rope_theta=1e4,
              is_global=is_global, window=window)
    out_banded = A.attention_full(params, x, **kw)      # cond path (S=4096)
    # force the masked fallback by making the band as wide as S
    monkeypatch.setattr(A, "Q_CHUNK", S)                # Wlen = S+window >= S
    out_masked = A.attention_full(params, x, **kw)
    np.testing.assert_allclose(out_banded, out_masked, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("is_global", [False, True])
def test_decode_banded_matches_masked_mixed_positions(is_global, rng):
    B, S, H, Hkv, hd = 3, 128, 2, 1, 16
    window = 32
    d = H * hd
    params = A.init_attention(rng, d, H, Hkv, hd, jnp.float32)
    x = 0.3 * jax.random.normal(rng, (B, 1, d))
    kc = 0.3 * jax.random.normal(jax.random.fold_in(rng, 1), (B, Hkv, S, hd))
    vc = 0.3 * jax.random.normal(jax.random.fold_in(rng, 2), (B, Hkv, S, hd))
    pos = jnp.asarray([5, 60, 120])       # mixed depths (continuous batching)
    kw = dict(num_heads=H, num_kv_heads=Hkv, head_dim=hd, rope_theta=1e4,
              is_global=is_global)
    y_banded, _, _ = A.attention_decode(params, x, kc, vc, pos,
                                        window=window, **kw)
    # reference: masked-full via window >= S disables the banded branch but
    # keeps the locality mask -> emulate by huge cache? Instead compute the
    # oracle directly.
    def oracle():
        q = (x @ params["wq"]).reshape(B, 1, H, hd)
        k = (x @ params["wk"]).reshape(B, 1, Hkv, hd)
        v = (x @ params["wv"]).reshape(B, 1, Hkv, hd)
        from repro.models.layers import apply_rope
        q = apply_rope(q, pos[:, None], 1e4)
        k = apply_rope(k, pos[:, None], 1e4)
        write = (jnp.arange(S)[None, :] == pos[:, None])
        kcc = jnp.where(write[:, None, :, None], k.transpose(0, 2, 1, 3), kc)
        vcc = jnp.where(write[:, None, :, None], v.transpose(0, 2, 1, 3), vc)
        G = H // Hkv
        qg = q.reshape(B, 1, Hkv, G, hd)
        s = jnp.einsum("bshgd,bhtd->bhgst", qg, kcc) / jnp.sqrt(
            jnp.float32(hd))
        idx = jnp.arange(S)
        ok = idx[None, :] <= pos[:, None]
        if not is_global:
            ok &= idx[None, :] > (pos[:, None] - window)
        s = jnp.where(ok[:, None, None, None, :], s, -2.0e38)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgst,bhtd->bshgd", p, vcc)
        return (o.reshape(B, 1, H * hd) @ params["wo"])

    np.testing.assert_allclose(y_banded, oracle(), rtol=2e-4, atol=2e-4)
