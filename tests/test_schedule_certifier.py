"""Schedule hazard certifier + dependence analysis (ISSUE 7 acceptance).

Real scheduler traces — recorded by ``ServingEngine(certify=True)`` and by
a raw ``JitSession(record_trace=True)`` — must certify clean; mutated
traces (same records, one illegal edit) must each be rejected with the
expected ``HazardViolation`` subclass. Mutation sites are chosen
property-style via ``_hypothesis_compat``: under real hypothesis the index
strategies explore the trace, under the fallback they sweep a fixed grid.
"""
import copy
import dataclasses
import math

import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.analysis import (ConservationHazard, DeadlineHazard, DepEdge,
                            EnvAliasHazard, KVAliasHazard,
                            OperandIdentityHazard, ProgramOrderHazard,
                            build_depgraph, certify_trace,
                            check_conservation, cross_program_conflicts)
from repro.configs import smoke_config
from repro.core.jit import (JitStats, VLIWJit, build_dense_decode_program,
                            build_dense_decode_template)
from repro.models import Model
from repro.serving import ServeRequest, ServingEngine, Tenant, two_wave_trace


@pytest.fixture(scope="module")
def dense_models():
    out = {}
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    return out


@pytest.fixture(scope="module")
def served(dense_models):
    """One real certified serve: two same-arch tenants (identical GEMM
    shapes, distinct weights) arriving together, so decode steps coalesce
    into cross-tenant groups — the regime every group-level hazard check
    is about. Returns (report, recorded ScheduleTrace)."""
    m, _ = dense_models["gemma3-1b"]
    p1 = m.init(jax.random.PRNGKey(11))
    p2 = m.init(jax.random.PRNGKey(12))
    eng = ServingEngine([Tenant("t1", m, p1, cache_len=32, max_batch=2),
                         Tenant("t2", m, p2, cache_len=32, max_batch=2)],
                        mode="vliw", certify=True)
    gap = 1.5 * eng._prefill_time(m.cfg, 8)
    trace = two_wave_trace(["t1", "t2"], ["t1", "t2"], gap, prompt_len=8,
                           max_new_tokens=4, slo_s=1.0)
    rep = eng.run(trace)
    return rep, eng.last_trace


def _prog_positions(trace):
    """(dispatch_idx, op_idx) sites per prog_uid, in dispatch order."""
    pos = {}
    for di, d in enumerate(trace.dispatches):
        for oi, op in enumerate(d.ops):
            if op.prog_uid:
                pos.setdefault(op.prog_uid, []).append((di, oi))
    return pos


def _coalesced_dispatches(trace):
    """Dispatch indices whose group spans >= 2 distinct programs."""
    return [di for di, d in enumerate(trace.dispatches)
            if len({op.prog_uid for op in d.ops if op.prog_uid}) >= 2]


def _replace_op(trace, di, oi, **changes):
    d = trace.dispatches[di]
    ops = list(d.ops)
    ops[oi] = dataclasses.replace(ops[oi], **changes)
    trace.dispatches[di] = dataclasses.replace(d, ops=tuple(ops))


# ---------------------------------------------------------------------------
# clean traces certify clean
# ---------------------------------------------------------------------------

def test_real_serving_trace_certifies_clean(served):
    rep, trace = served
    assert rep.jit.hazard_checks > 0
    assert rep.jit.hazard_violations == 0
    # the trace is a real one: coalesced cross-tenant groups, declared KV
    # footprints, and a full request lifecycle
    assert trace.dispatches and trace.req_admits and trace.req_retires
    assert _coalesced_dispatches(trace)
    assert any(pa.kv_writes for pa in trace.prog_admits)
    cert = certify_trace(trace, raise_on_violation=False)
    assert cert.violations == [] and cert.checks > 0


def test_raw_session_trace_certifies_clean(dense_models, rng):
    """The session-level trace path (no engine): two concurrent dense
    decode programs, driven to completion tick by tick."""
    m, params = dense_models["gemma3-1b"]
    batch = {"tokens": jax.random.randint(rng, (2, 8), 0, m.cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=32)
    tok = jax.random.randint(jax.random.fold_in(rng, 3), (2, 1), 0,
                             m.cfg.vocab_size)
    jit = VLIWJit(max_group=8)
    session = jit.session(record_trace=True)
    for sid in (0, 1):
        session.admit(build_dense_decode_program(
            m, params, tok, copy.deepcopy(cache), stream_id=sid))
    now = 0.0
    while session.live:
        ev = session.tick(now)
        now = max(now, ev.t)
    assert session.trace.dispatches
    assert all(op.prog_uid for d in session.trace.dispatches
               for op in d.ops)
    cert = certify_trace(session.trace, raise_on_violation=False)
    assert cert.violations == [] and cert.checks > 0


def test_hazard_counters_fold_through_merge():
    a = JitStats(hazard_checks=3, hazard_violations=1)
    b = JitStats(hazard_checks=2, hazard_violations=0)
    assert a.merge(b) is a
    assert a.hazard_checks == 5 and a.hazard_violations == 1


# ---------------------------------------------------------------------------
# mutated traces are rejected with the expected violation class
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 7))
def test_swapping_same_stream_ops_is_program_order_hazard(served, idx):
    """Reordering two ops of one program (the OoO move the scheduler must
    never make) is caught as a seq regression."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    progs = [(uid, ps) for uid, ps in sorted(_prog_positions(trace).items())
             if len(ps) >= 2]
    assert progs
    uid, ps = progs[idx % len(progs)]
    (d1, o1), (d2, o2) = ps[0], ps[-1]
    assert d1 != d2      # a legal trace never groups two same-stream ops
    a, b = trace.dispatches[d1].ops[o1], trace.dispatches[d2].ops[o2]
    _replace_op(trace, d1, o1, seq=b.seq, tag=b.tag)
    _replace_op(trace, d2, o2, seq=a.seq, tag=a.tag)
    with pytest.raises(ProgramOrderHazard):
        certify_trace(trace)
    cert = certify_trace(trace, raise_on_violation=False)
    assert any(isinstance(v, ProgramOrderHazard) for v in cert.violations)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 7))
def test_dropping_a_retire_is_conservation_hazard(served, idx):
    """Every admitted request must retire / evict / surface unfinished —
    deleting one retirement breaks the balance."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    assert trace.req_retires
    rid, _ = trace.req_retires.pop(idx % len(trace.req_retires))
    if rid in trace.evicted or rid in trace.unfinished:
        pytest.skip("request covered by another lifecycle set")
    with pytest.raises(ConservationHazard):
        check_conservation(trace)
    vs = check_conservation(trace, raise_on_violation=False)
    assert vs and all(isinstance(v, ConservationHazard) for v in vs)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 7))
def test_duplicate_admission_is_conservation_hazard(served, idx):
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    trace.req_admits.append(trace.req_admits[idx % len(trace.req_admits)])
    with pytest.raises(ConservationHazard):
        check_conservation(trace)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 3))
def test_aliased_kv_slots_are_kv_hazard(served, idx):
    """Two tenants' programs claiming the same KV row must not share a
    concurrent group."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    cds = _coalesced_dispatches(trace)
    assert cds
    di = cds[idx % len(cds)]
    row = ("kv", "t1", 0)
    for oi in range(len(trace.dispatches[di].ops)):
        _replace_op(trace, di, oi, kv_writes=(row,))
    with pytest.raises(KVAliasHazard):
        certify_trace(trace)
    cert = certify_trace(trace, raise_on_violation=False)
    assert any(isinstance(v, KVAliasHazard) for v in cert.violations)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 3))
def test_shared_weight_key_across_distinct_params_is_operand_hazard(
        served, idx):
    """A shared-operand dispatch whose ops resolved to different weight
    arrays would serve one tenant the other's weights."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    cds = _coalesced_dispatches(trace)
    assert cds
    di = cds[idx % len(cds)]
    d = trace.dispatches[di]
    for oi in range(len(d.ops)):
        _replace_op(trace, di, oi, weight_key=("shared", "wq"),
                    weight_id=(0xBAD + oi,))
    trace.dispatches[di] = dataclasses.replace(
        trace.dispatches[di], shared_operand=True)
    with pytest.raises(OperandIdentityHazard):
        certify_trace(trace)
    cert = certify_trace(trace, raise_on_violation=False)
    assert any(isinstance(v, OperandIdentityHazard)
               for v in cert.violations)


def test_shared_env_object_is_env_hazard(served):
    """Two programs writing the same key of one (supposedly private) env
    object in one group."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    di = _coalesced_dispatches(trace)[0]
    for oi in range(len(trace.dispatches[di].ops)):
        _replace_op(trace, di, oi, env_id=0xE17, env_writes=("x",))
    with pytest.raises(EnvAliasHazard):
        certify_trace(trace)


def test_undeclared_env_writes_alias_everything(served):
    """The conservative wildcard: an op with UNDECLARED writes conflicts
    with any declared writer of the same env object."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    di = _coalesced_dispatches(trace)[0]
    _replace_op(trace, di, 0, env_id=0xE17, env_writes=("*",))
    _replace_op(trace, di, 1, env_id=0xE17, env_writes=("hf",))
    with pytest.raises(EnvAliasHazard):
        certify_trace(trace)


def test_latest_start_regression_is_deadline_hazard(served):
    """latest_start_t must be non-decreasing within a program (the
    remaining GEMM-suffix critical path only shrinks)."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    progs = [(uid, ps) for uid, ps in sorted(_prog_positions(trace).items())
             if len(ps) >= 2]
    uid, ps = progs[0]
    (d1, o1), (d2, o2) = ps[0], ps[-1]
    first = trace.dispatches[d1].ops[o1]
    _replace_op(trace, d2, o2, latest_start_t=first.latest_start_t - 1.0)
    with pytest.raises(DeadlineHazard):
        certify_trace(trace)


def test_deadline_drift_is_deadline_hazard(served):
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    progs = [(uid, ps) for uid, ps in sorted(_prog_positions(trace).items())
             if len(ps) >= 2]
    uid, ps = progs[0]
    (d2, o2) = ps[-1]
    old = trace.dispatches[d2].ops[o2].deadline_t
    drifted = 0.5 * old if math.isfinite(old) else 1.0
    _replace_op(trace, d2, o2, deadline_t=drifted)
    with pytest.raises(DeadlineHazard):
        certify_trace(trace)


def test_same_stream_ops_in_one_group_is_program_order_hazard(served):
    """Packing two ops of one stream into a single concurrent group —
    even in the right order — executes an intra-stream dependence
    'simultaneously'."""
    _, trace0 = served
    trace = copy.deepcopy(trace0)
    progs = [(uid, ps) for uid, ps in sorted(_prog_positions(trace).items())
             if len(ps) >= 2]
    uid, ps = progs[0]
    (d1, o1), (d2, o2) = ps[0], ps[1]
    moved = trace.dispatches[d2].ops[o2]
    d = trace.dispatches[d1]
    trace.dispatches[d1] = dataclasses.replace(d, ops=d.ops + (moved,))
    with pytest.raises(ProgramOrderHazard):
        certify_trace(trace)


# ---------------------------------------------------------------------------
# engine-level satellites
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["vliw", "batched", "time"])
def test_duplicate_req_id_admission_rejected(dense_models, mode):
    """Request ids key prompt synthesis, eviction dedup and conservation;
    a colliding trace must be rejected up front in EVERY mode."""
    m, p = dense_models["gemma3-1b"]
    eng = ServingEngine([Tenant("a", m, p, cache_len=32, max_batch=2)],
                        mode=mode)
    reqs = [ServeRequest(5, "a", 0.0, 8, 2, 1.0),
            ServeRequest(5, "a", 1e-6, 8, 2, 1.0)]
    with pytest.raises(ValueError, match="duplicate req_id"):
        eng.run(reqs)


def test_unique_req_ids_still_admit(dense_models):
    m, p = dense_models["gemma3-1b"]
    eng = ServingEngine([Tenant("a", m, p, cache_len=32, max_batch=2)],
                        mode="vliw", certify=True)
    reqs = [ServeRequest(0, "a", 0.0, 8, 2, 1.0),
            ServeRequest(1, "a", 1e-6, 8, 2, 1.0)]
    rep = eng.run(reqs)
    assert rep.unfinished == 0
    assert rep.jit.hazard_violations == 0 and rep.jit.hazard_checks > 0


# ---------------------------------------------------------------------------
# static dependence graphs
# ---------------------------------------------------------------------------

class _St:
    """Minimal stage stand-in: only the declared access sets matter."""

    def __init__(self, tag, reads=None, writes=None):
        self.tag = tag
        if reads is not None:
            self.reads = tuple(reads)
        if writes is not None:
            self.writes = tuple(writes)


def test_depgraph_raw_war_waw():
    g = build_depgraph([_St("a", reads=("cache",), writes=("x",)),
                        _St("b", reads=("x",), writes=("h",)),
                        _St("c", reads=(), writes=("x",))])
    kinds = {(e.kind, e.src, e.dst, e.resource) for e in g.edges}
    assert ("RAW", 0, 1, "x") in kinds
    assert ("WAR", 1, 2, "x") in kinds
    assert ("WAW", 0, 2, "x") in kinds
    assert not g.conservative
    assert not g.unsourced_reads          # "cache" is bind-time


def test_depgraph_undeclared_stage_is_barrier():
    g = build_depgraph([_St("a", reads=(), writes=("x",)),
                        _St("mystery"),                   # undeclared
                        _St("c", reads=("x",), writes=("y",))])
    assert g.conservative == [1]
    assert any(e.kind == "WAW" and (e.src, e.dst) == (0, 1)
               for e in g.edges)
    # the wildcard writer is the last writer of everything it clobbered
    assert any(e.kind == "RAW" and (e.src, e.dst) == (1, 2)
               for e in g.edges)


def test_depgraph_flags_unsourced_reads():
    g = build_depgraph([_St("a", reads=("bogus",), writes=("x",))])
    assert g.unsourced_reads == [(0, "bogus")]


@pytest.mark.parametrize("stacked", [True, False])
def test_dense_decode_template_fully_declared(dense_models, stacked):
    """Every stage the dense builders emit declares its access sets (no
    conservative wildcards) and every read has a source: an upstream
    writer or a bind-time binding."""
    m, p = dense_models["gemma3-1b"]
    template = build_dense_decode_template(m, p, 2, stacked=stacked)
    g = build_depgraph(template)
    assert not g.conservative
    assert not g.unsourced_reads
    # the spine is a RAW chain through "x" (embed -> layers -> final norm)
    assert any(e.kind == "RAW" and e.resource == "x" for e in g.edges)
    assert g.predecessors(len(g.labels) - 1)


def test_cross_program_conflicts_kv_and_env():
    env = {}
    a = _NSProg(kv_writes=(("kv", "t", 0),), env=env,
                stages=[_St("s", reads=(), writes=("x",))])
    b = _NSProg(kv_writes=(("kv", "t", 0), ("kv", "t", 1)), env={},
                stages=[])
    assert cross_program_conflicts(a, b) == [("kv", ("kv", "t", 0))]
    c = _NSProg(kv_writes=(), env=env,
                stages=[_St("s", reads=(), writes=("x", "y"))])
    assert ("env", "x") in cross_program_conflicts(a, c)
    d = _NSProg(kv_writes=(("kv", "u", 0),), env={}, stages=[])
    assert cross_program_conflicts(b, d) == []


class _NSProg:
    def __init__(self, kv_writes, env, stages):
        self.kv_writes = kv_writes
        self.env = env
        self.stages = stages


def test_served_programs_have_disjoint_footprints(served):
    """The engine's declared per-tenant KV rows really are disjoint across
    tenants — the static justification for cross-tenant coalescing."""
    _, trace = served
    by_stream = {}
    for pa in trace.prog_admits:
        by_stream.setdefault(pa.stream, set()).update(pa.kv_writes)
    streams = sorted(by_stream)
    assert len(streams) >= 2
    for i in streams:
        for j in streams:
            if i < j:
                assert not (by_stream[i] & by_stream[j])
