"""Layer-stacked KernelPrograms (ISSUE 6 tentpole): scan-over-layers
templates must be BIT-identical to the per-layer oracle emission
(``stacked=False``) — logits and cache leaves, not just tokens — across
dense decode, dense prefill, MoE and SSM at several batch sizes; the
stacked dispatch path must keep the steady-state plan-cache hit rate and
the packed-weight guard discipline (zero phantom invalidations, real
hot-swaps trip the guard); and a production-depth (48-layer) config must
serve end-to-end through the vliw mode with O(1)-in-depth templates."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.jit import (StackedGemmStage, VLIWJit,
                            build_dense_decode_template,
                            build_dense_prefill_template,
                            build_moe_decode_template,
                            build_ssm_decode_template, partition_layers,
                            prefill_bucket)
from repro.models import Model
from repro.serving import ServeRequest, ServingEngine, Tenant

DECODE_BUILDERS = {
    "dense": build_dense_decode_template,
    "moe": build_moe_decode_template,
    "ssm": build_ssm_decode_template,
}
ARCHS = {"dense": "gemma3-1b", "moe": "grok-1-314b", "ssm": "mamba2-2.7b"}


@pytest.fixture(scope="module")
def models():
    out = {}
    for fam, arch in ARCHS.items():
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[fam] = (m, m.init(jax.random.PRNGKey(hash(fam) % 1000)))
    return out


def _decode_steps(build, m, params, cache, tok, *, stacked, steps=3):
    """Run ``steps`` greedy decode steps through a (re-bound) template."""
    tmpl = build(m, params, int(tok.shape[0]), stacked=stacked)
    vj = VLIWJit(max_group=8)
    logits = []
    for _ in range(steps):
        prog = tmpl.bind(stream_id=0, tokens=tok, cache=cache)
        vj.run([prog])
        logits.append(np.asarray(prog.env["logits"]))
        cache = prog.env["cache"]
        tok = jnp.argmax(prog.env["logits"],
                         axis=-1).astype(jnp.int32)[:, None]
    return logits, cache


def _setup(m, params, B, S=12, CL=32):
    rng = jax.random.PRNGKey(3)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0,
                                          m.cfg.vocab_size)}
    _, cache = m.prefill(params, batch, cache_len=CL)
    tok = jax.random.randint(jax.random.fold_in(rng, 9), (B, 1), 0,
                             m.cfg.vocab_size)
    return cache, tok


# ---------------------------------------------------------------------------
# bit-identity: stacked vs per-layer oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 2, 4])
@pytest.mark.parametrize("fam", ["dense", "moe", "ssm"])
def test_stacked_decode_bit_identical_to_per_layer(fam, batch, models):
    """The tentpole contract: the scanned layer body computes the SAME
    BITS as the per-layer executor dispatch — logits AND every recurrent
    cache leaf, over multiple steps (divergence would compound)."""
    m, params = models[fam]
    cache0, tok = _setup(m, params, batch)
    want, want_cache = _decode_steps(DECODE_BUILDERS[fam], m, params,
                                     cache0, tok, stacked=False)
    got, got_cache = _decode_steps(DECODE_BUILDERS[fam], m, params,
                                   cache0, tok, stacked=True)
    for s, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(a, b, err_msg=f"step {s}")
    for leaf in want_cache["layers"]:
        np.testing.assert_array_equal(
            np.asarray(got_cache["layers"][leaf]),
            np.asarray(want_cache["layers"][leaf]), err_msg=leaf)


@pytest.mark.parametrize("prompt_len", [5, 12])
def test_stacked_prefill_bit_identical_to_per_layer(prompt_len, models):
    m, params = models["dense"]
    cfg = m.cfg
    Sp = prefill_bucket(prompt_len)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, prompt_len), 0,
                              cfg.vocab_size)
    padded = jnp.pad(toks, ((0, 0), (0, Sp - prompt_len)))
    outs = {}
    for stacked in (True, False):
        cache = m.init_cache(2, 32)
        tmpl = build_dense_prefill_template(m, params, Sp, stacked=stacked)
        prog = tmpl.bind(stream_id=0, tokens=padded, cache=cache,
                         env_extra={"real_len": prompt_len, "slot": 1})
        VLIWJit(max_group=8).run([prog])
        outs[stacked] = prog.env
    np.testing.assert_array_equal(np.asarray(outs[True]["logits"]),
                                  np.asarray(outs[False]["logits"]))
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(outs[True]["cache"]["layers"][leaf]),
            np.asarray(outs[False]["cache"]["layers"][leaf]))


def test_stacked_template_one_body_stage_per_substack(models):
    """Structure: stage count is O(1) in depth — one StackedGemmStage per
    homogeneous sub-stack, never a per-layer emission."""
    m, params = models["dense"]
    tmpl = build_dense_decode_template(m, params, 2, stacked=True)
    bodies = [st for st in tmpl.stages if isinstance(st, StackedGemmStage)]
    assert len(bodies) == len(partition_layers(
        m.cfg.global_layer_flags()))
    per_layer = build_dense_decode_template(m, params, 2, stacked=False)
    assert len(tmpl.stages) < len(per_layer.stages)


# ---------------------------------------------------------------------------
# serving: engine-level token identity, hit rate, hot-swap
# ---------------------------------------------------------------------------

def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def test_engine_stacked_vs_per_layer_token_identity(models):
    m, params = models["dense"]
    trace = [ServeRequest(0, "a", 0.0, 8, 4, 1.0),
             ServeRequest(1, "a", 1e-4, 6, 4, 1.0)]
    reps = {}
    for stacked in (True, False):
        eng = ServingEngine([Tenant("a", m, params, cache_len=32,
                                    max_batch=2)], mode="vliw",
                            stacked_layers=stacked)
        reps[stacked] = eng.run(trace)
    assert _tokens(reps[True]) == _tokens(reps[False])


def test_stacked_steady_state_hit_rate_and_guard(models):
    """Steady state through the stacked path: plan-cache miss only on the
    first step, and the stacked weight closures hand the executor STABLE
    arrays — zero phantom hot-swap invalidations."""
    m, params = models["dense"]
    steps = 5
    trace = [ServeRequest(0, "a", 0.0, 8, steps + 1, 1.0)]
    eng = ServingEngine([Tenant("a", m, params, cache_len=32,
                                max_batch=2)], mode="vliw")
    assert eng.stacked_layers          # stacked is the default regime
    rep = eng.run(trace)
    pc = rep.jit.plan_cache
    assert pc.hit_rate >= (steps - 1) / steps - 1e-9
    assert pc.invalidations == 0
    assert rep.jit.dispatch.weight_invalidations == 0
    assert rep.jit.dispatch.weight_hits > 0
    # stacked dispatch accounting stays consistent with plain dispatch
    d = rep.jit.dispatch
    assert d.weight_hits + d.weight_misses == d.dispatches


def test_stacked_hot_swap_trips_guard(models):
    """A real weight hot-swap must invalidate the stacked operand cache
    (new params identity → new weight keys + plan-cache invalidation) and
    converge to the same tokens as a fresh engine on the new weights."""
    m, p_old = models["dense"]
    p_new = Model(m.cfg, param_dtype=jnp.float32).init(
        jax.random.PRNGKey(77))
    trace1 = [ServeRequest(0, "a", 0.0, 8, 3, 1.0)]
    trace2 = [ServeRequest(1, "a", 0.0, 8, 3, 1.0)]
    eng = ServingEngine([Tenant("a", m, p_old, cache_len=32, max_batch=2)],
                        mode="vliw")
    eng.run(trace1)
    assert eng.jit.plan_cache.stats.invalidations == 0
    eng.tenants["a"].params = p_new      # hot-swap, same model object
    rep_swapped = eng.run(trace2)
    assert eng.jit.plan_cache.stats.invalidations >= 1
    fresh = ServingEngine([Tenant("a", m, p_new, cache_len=32,
                                  max_batch=2)], mode="vliw")
    rep_fresh = fresh.run(trace2)
    assert _tokens(rep_swapped) == _tokens(rep_fresh)


# ---------------------------------------------------------------------------
# production depth: 48 layers end-to-end (the tier-1 depth smoke)
# ---------------------------------------------------------------------------

def test_depth_48_serves_end_to_end():
    """A granite-34b-shaped config at REAL depth (48 layers, smoke dims)
    serves through the vliw mode — possible only because templates are
    O(1) in depth — with greedy tokens identical to the batched mode."""
    cfg = dataclasses.replace(smoke_config("granite-34b"), num_layers=48)
    assert len(partition_layers(cfg.global_layer_flags())) == 1
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(8))
    trace = [ServeRequest(0, "a", 0.0, 6, 3, 1.0)]
    reps = {}
    for mode in ("vliw", "batched"):
        eng = ServingEngine([Tenant("a", m, params, cache_len=32,
                                    max_batch=2)], mode=mode)
        reps[mode] = eng.run(trace)
    toks = _tokens(reps["vliw"])
    assert toks == _tokens(reps["batched"])
    assert all(len(t) == 3 for t in toks)
    # the stacked emission really is depth-independent: the 48-layer
    # template has exactly as many stages as a 2-layer one
    t48 = build_dense_decode_template(m, params, 1, stacked=True)
    shallow = Model(smoke_config("granite-34b"), param_dtype=jnp.float32)
    p2 = shallow.init(jax.random.PRNGKey(8))
    t2 = build_dense_decode_template(shallow, p2, 1, stacked=True)
    assert len(t48.stages) == len(t2.stages)
