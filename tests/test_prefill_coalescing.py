"""Prefill through the JIT (ISSUE 3): prompt GEMMs as first-class declared
ops that coalesce with decode (and other tenants' prefill) traffic, the
serving-metric bugfixes, and the event-loop stall guard."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.clustering import group_ops_exact
from repro.core.costmodel import GemmShape
from repro.core.jit import (VLIWJit, build_dense_prefill_template,
                            prefill_bucket, prefill_program_cache_key)
from repro.core.kernelspec import make_op
from repro.models import Model
from repro.serving import (ServeReport, ServeRequest, ServingEngine, Tenant,
                           long_prompt_trace)


@pytest.fixture(scope="module")
def dense_models():
    out = {}
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    return out


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


# ---------------------------------------------------------------------------
# units: buckets + cross-aspect grouping
# ---------------------------------------------------------------------------

def test_prefill_bucket_powers_of_two():
    assert prefill_bucket(1) == 8
    assert prefill_bucket(8) == 8
    assert prefill_bucket(9) == 16
    assert prefill_bucket(33) == 64
    assert prefill_bucket(256) == 256
    assert prefill_bucket(257) == 512


def test_group_ops_exact_merges_prefill_gemms_with_decode_gemvs():
    """The coalescing key is (n, k, dtype) only: a 256-row prefill GEMM and
    a 4-row decode GEMV sharing weight dims land in ONE group (coalesced
    kernels concatenate along m), instead of being split by aspect."""
    dec = make_op(0, "gemv", GemmShape(4, 128, 128), op_kind="decode")
    pre = make_op(1, "gemm", GemmShape(256, 128, 128), op_kind="prefill")
    other = make_op(2, "gemm", GemmShape(256, 256, 128), op_kind="prefill")
    groups = group_ops_exact([dec, pre, other])
    assert len(groups) == 2
    assert sorted(len(v) for v in groups.values()) == [1, 2]
    merged = next(v for v in groups.values() if len(v) == 2)
    assert {o.op_kind for o in merged} == {"decode", "prefill"}


# ---------------------------------------------------------------------------
# the prefill program computes exactly what Model.prefill computes
# ---------------------------------------------------------------------------

def test_prefill_program_matches_model_prefill(rng):
    """A declared prefill program (padded to its bucket, run through real
    superkernel dispatches) reproduces Model.prefill's last-position logits
    and writes exactly the KV slot rows the analytic admission writes."""
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    params = m.init(rng)
    s = 13                                    # odd length: real padding
    prompt = jax.random.randint(jax.random.fold_in(rng, 7), (1, s), 0,
                                cfg.vocab_size)
    want_logits, pc = m.prefill(params, {"tokens": prompt}, cache_len=32)

    bucket = prefill_bucket(s)
    assert bucket == 16
    template = build_dense_prefill_template(m, params, bucket)
    cache = m.init_cache(2, 32)
    padded = jnp.pad(prompt, ((0, 0), (0, bucket - s)))
    prog = template.bind(stream_id=0, tokens=padded, cache=cache,
                         env_extra={"real_len": s, "slot": 1, "req": None})
    VLIWJit(max_group=8).run([prog])

    np.testing.assert_allclose(prog.env["logits"], want_logits[0],
                               rtol=2e-4, atol=2e-4)
    assert int(jnp.argmax(prog.env["logits"][0])) \
        == int(jnp.argmax(want_logits[0, -1]))
    got = prog.env["cache"]
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(got["layers"][key][:, 1]),
                                   np.asarray(pc["layers"][key][:, 0]),
                                   rtol=2e-4, atol=2e-4)
        # the untouched slot's row stays zero (and so does the padded tail)
        assert np.all(np.asarray(got["layers"][key][:, 0]) == 0)
    assert int(got["pos"][1]) == s and int(got["pos"][0]) == 0


# ---------------------------------------------------------------------------
# engine: long prompts stay bit-identical across modes AND coalesce
# ---------------------------------------------------------------------------

def test_long_prompt_modes_identical_and_prefill_coalesces(dense_models):
    """Acceptance core: on a multi-tenant long-prompt trace, vliw dispatches
    at least one superkernel group containing a prefill op together with
    another tenant's op, and greedy tokens stay bit-identical across all
    three modes (prompt lengths jittered across prefill buckets)."""
    m1, p1 = dense_models["gemma3-1b"]
    m2, p2 = dense_models["yi-9b"]

    def tenants():
        return [Tenant("a", m1, p1, cache_len=64, max_batch=2),
                Tenant("b", m2, p2, cache_len=64, max_batch=2)]

    trace = long_prompt_trace(["a", "b"], prompt_len=40, max_new_tokens=3,
                              n_per_tenant=2, stagger_s=1e-6,
                              prompt_jitter=17, seed=3)
    assert len({prefill_bucket(r.prompt_len) for r in trace}) >= 1
    reps = {}
    for mode in ("time", "batched", "vliw"):
        eng = ServingEngine(tenants(), mode=mode)
        reps[mode] = eng.run(trace)
        assert all(len(r.tokens_out) == 3 for r in reps[mode].requests)
    assert _tokens(reps["time"]) == _tokens(reps["batched"]) \
        == _tokens(reps["vliw"])
    jit = reps["vliw"].jit
    assert jit.prefill_coalesced >= 1
    # declared prefill must not regress the makespan vs the analytic
    # serialized-prefill ablation of the same engine
    ablate = ServingEngine(tenants(), mode="vliw", declared_prefill=False)
    rep_ablate = ablate.run(trace)
    assert _tokens(rep_ablate) == _tokens(reps["vliw"])
    assert reps["vliw"].modeled_time_s <= rep_ablate.modeled_time_s * 1.001


def test_single_token_request_retires_at_prefill_completion(dense_models):
    """max_new_tokens=1 through the DECLARED path: the request's only token
    comes from the prefill program's logits, it never takes a decode slot,
    and it finishes at the completion event."""
    m1, p1 = dense_models["gemma3-1b"]

    def tenants():
        return [Tenant("a", m1, p1, cache_len=32, max_batch=2)]

    trace = [ServeRequest(0, "a", 0.0, 17, 1, 1.0)]
    reps = {}
    for mode in ("batched", "vliw"):
        eng = ServingEngine(tenants(), mode=mode)
        reps[mode] = eng.run(trace)
    assert _tokens(reps["batched"]) == _tokens(reps["vliw"])
    (req,) = reps["vliw"].requests
    assert len(req.tokens_out) == 1
    assert not math.isnan(req.finish_t)
    assert reps["vliw"].unfinished == 0


def test_prefill_templates_cached_per_bucket(dense_models):
    """Prompt lengths sharing a power-of-two bucket share ONE compiled
    prefill template (finite plan-cache key space); a new bucket compiles a
    new one."""
    m1, p1 = dense_models["gemma3-1b"]
    t = Tenant("a", m1, p1, cache_len=64, max_batch=4)
    eng = ServingEngine([t], mode="vliw")
    trace = [ServeRequest(0, "a", 0.0, 17, 2, 1.0),
             ServeRequest(1, "a", 0.1, 20, 2, 1.0),   # same bucket (32)
             ServeRequest(2, "a", 0.2, 33, 2, 1.0)]   # new bucket (64)
    eng.run(trace)
    pf_keys = [k for k in eng.jit.plan_cache.keys()
               if k[0] == "dense-prefill"]
    assert len(pf_keys) == 2
    assert {k[3] for k in pf_keys} == {32, 64}


# ---------------------------------------------------------------------------
# ServeReport metric bugfixes
# ---------------------------------------------------------------------------

def _req(rid, max_new, emitted, finish_t):
    r = ServeRequest(rid, "a", 0.0, 4, max_new, slo_s=2.0)
    r.tokens_out = [1] * emitted if emitted else None
    r.finish_t = finish_t
    return r


def test_tokens_per_s_counts_emitted_not_requested():
    """Regression: throughput used to count max_new_tokens even for
    unfinished / early-retired requests."""
    reqs = [_req(0, max_new=8, emitted=8, finish_t=1.0),
            _req(1, max_new=8, emitted=3, finish_t=float("nan")),
            _req(2, max_new=8, emitted=0, finish_t=float("nan"))]
    rep = ServeReport("vliw", reqs, modeled_time_s=1.0, wall_time_s=0.0)
    assert rep.tokens_per_s == pytest.approx(11.0)   # not 24.0


def test_latency_stats_count_unfinished_requests():
    """Regression (front-door sweep): ``mean_latency`` stays finished-only
    (a NaN finish used to poison the whole mean), but attainment and
    percentile latency now COUNT unfinished/shed requests — as misses and
    as +inf latencies — instead of silently excluding them, which inflated
    both the moment anything was dropped."""
    reqs = [_req(0, max_new=4, emitted=4, finish_t=1.0),
            _req(1, max_new=4, emitted=4, finish_t=3.0),
            _req(2, max_new=4, emitted=1, finish_t=float("nan"))]
    rep = ServeReport("vliw", reqs, modeled_time_s=1.0, wall_time_s=0.0)
    assert rep.unfinished == 1
    assert rep.mean_latency == pytest.approx(2.0)   # finished-only
    assert rep.p_latency(0.5) == pytest.approx(3.0)
    assert rep.p_latency(1.0) == math.inf            # the drop is visible
    # slo_s=2.0: req 0 meets (1.0), req 1 misses (3.0), req 2 never
    # finished — a miss, not an exclusion
    assert rep.slo_attainment == pytest.approx(1.0 / 3.0)

    none_done = ServeReport("vliw", [_req(0, 4, 1, float("nan"))],
                            modeled_time_s=1.0, wall_time_s=0.0)
    assert none_done.unfinished == 1
    assert math.isnan(none_done.mean_latency)
    assert none_done.p_latency(0.5) == math.inf
    assert none_done.slo_attainment == 0.0


# ---------------------------------------------------------------------------
# event-loop stall guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("declared", [True, False])
def test_event_loop_stall_guard_terminates(dense_models, declared):
    """A due request that can never be admitted (here: a tenant with zero
    decode slots), with pending exhausted and nothing inflight, must
    TERMINATE the event loop — the ``if not progressed`` branch used to
    spin forever when ``waiting`` stayed non-empty. The dropped request
    surfaces in ServeReport.unfinished."""
    m1, p1 = dense_models["gemma3-1b"]
    t = Tenant("a", m1, p1, cache_len=32, max_batch=0)
    eng = ServingEngine([t], mode="vliw", declared_prefill=declared)
    # prompt >= prefill_declare_min so declared=True exercises the
    # _declare_prefill no-free-slot refusal, not the analytic one
    trace = [ServeRequest(0, "a", 0.0, 16, 4, 1.0)]
    rep = eng.run(trace)                  # must return, not livelock
    assert rep.unfinished == 1
    assert math.isnan(rep.requests[0].finish_t)
