import jax
import pytest

# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see ONE device; only launch/dryrun.py uses 512.


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="module", autouse=True)
def _release_jax_caches():
    # The whole suite shares one process, so every jitted executable from
    # every module stays live until exit; past ~300 tests the accumulated
    # XLA state can crash the CPU compiler outright. Dropping jax's caches
    # at module teardown keeps the high-water mark at one module's worth.
    # (Our own PlanCache instances are per-test and unaffected.)
    yield
    jax.clear_caches()
