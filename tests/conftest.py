import jax
import pytest

# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see ONE device; only launch/dryrun.py uses 512.


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
