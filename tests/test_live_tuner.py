"""Live collaborative autotuning on the JIT dispatch hot path (PR 9).

Covers: the ``Coalescer.block_for`` full-group-signature regression (a tile
tuned for one shape must not be imposed on a mixed group), ``LiveTuner``
objectives + tune-cache lifecycle (stable-group hits, re-tune on tenant
churn, device-keyed mesh isolation), and serving-level acceptance — live
tuning changes no tokens and survives weight hot-swaps untouched (tuning is
params-free).
"""
import copy

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import (Autotuner, BlockConfig, Coalescer, CostModel,
                        GemmShape, LiveTuner, TPUV5E, V100, group_signature)
from repro.core.clustering import exact_key
from repro.core.costmodel import DEFAULT_BLOCK
from repro.core.plancache import PlanCache
from repro.models import Model
from repro.serving import ServingEngine, Tenant
from repro.serving.workload import two_wave_trace

CM = CostModel(V100)
SA = GemmShape(m=784, n=512, k=1152, dtype_bytes=4)
SB = GemmShape(m=32, n=128, k=1152, dtype_bytes=4)   # differs in exact_key
# witness group where the two objectives pick DIFFERENT tiles (Table 1
# direction at group granularity — see test_tune_group_objectives_diverge)
WITNESS = [GemmShape(16, 2048, 2048)] * 8


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


# ---------------------------------------------------------------------------
# satellite 1: block_for group-signature regression + clamp
# ---------------------------------------------------------------------------

def test_block_for_tuned_table_requires_uniform_group():
    """Pre-fix, the AOT-table lookup keyed on exact_key(shapes[0]) only: a
    tile tuned for SA alone was silently imposed on a mixed [SA, SB] group
    (and [SB, SA] fell through — order-dependent tiling for the SAME
    group). The table must apply iff every member shares the tuned key."""
    tuned = BlockConfig(64, 256, 512)
    coal = Coalescer(CM, tuned_blocks={exact_key(SA): tuned})
    assert coal.block_for([SA]) == tuned
    assert coal.block_for([SA, SA]) == tuned          # uniform group: applies
    mixed = coal.block_for([SA, SB])
    assert mixed != tuned                              # mixed group: heuristic
    assert mixed == coal.block_for([SB, SA])           # and order-independent


def test_block_for_clamp():
    """The default tile clamps to the (padded) problem, MXU-aligned:
    bn = max(8, min(128, n)) — the dead pre-fix ``min(128, max(128, n))``
    always returned 128 even for n < 128."""
    coal = Coalescer(CM)
    assert coal.block_for([GemmShape(8, 4, 256)]).bn == 8
    assert coal.block_for([GemmShape(8, 64, 256)]).bn == 64
    assert coal.block_for([GemmShape(8, 512, 256)]).bn == 128
    b = coal.block_for([SA, SB])
    assert b.bm == 128 and b.bk == DEFAULT_BLOCK.bk


# ---------------------------------------------------------------------------
# tune_group objectives (Table 1 direction at coalesced-group granularity)
# ---------------------------------------------------------------------------

def test_tune_group_objectives_diverge():
    at = Autotuner(CM)
    collab = at.tune_group(WITNESS, "collaborative")
    greedy = at.tune_group(WITNESS, "greedy")
    assert collab != greedy
    # collaborative wins the coalesced group, greedy wins isolated
    env = WITNESS[0]
    assert CM.coalesced_time(WITNESS, collab) < CM.coalesced_time(WITNESS,
                                                                  greedy)
    assert CM.gemm_time(env, greedy) < CM.gemm_time(env, collab)


def test_tune_group_envelope_is_max_extents():
    """A mixed group tunes against the envelope (max extents), so the tuned
    tile is always VALID for every padded member (pow2, VMEM-bounded)."""
    at = Autotuner(CM)
    b = at.tune_group([SA, SB], "collaborative")
    for v in (b.bm, b.bn, b.bk):
        assert v & (v - 1) == 0
    assert b.vmem_usage(max(SA.k, SB.k), 4) <= CM.device.vmem_bytes


# ---------------------------------------------------------------------------
# LiveTuner cache lifecycle: stable hits, churn re-tune, device isolation
# ---------------------------------------------------------------------------

def test_live_tuner_stable_group_hits():
    pc = PlanCache(32)
    lt = LiveTuner(CM, pc)
    g = [SA] * 4
    b = lt.tune(g)
    assert (pc.stats.misses, pc.stats.hits) == (1, 0)
    for _ in range(5):                       # steady state: pure cache hits
        assert lt.tune(list(g)) == b
    assert (pc.stats.misses, pc.stats.hits) == (1, 5)
    assert pc.stats.hit_rate == pytest.approx(5 / 6)


def test_live_tuner_churn_retunes_once_and_keeps_previous():
    """Group churn 8 -> 5 tenants: the new signature tunes ONCE; the old
    signature's entry stays served (churn back = hit, no re-search)."""
    pc = PlanCache(32)
    lt = LiveTuner(CM, pc)
    g8, g5 = [SA] * 8, [SA] * 5
    b8 = lt.tune(g8)
    b5 = lt.tune(g5)                          # churn: one fresh search
    assert pc.stats.misses == 2
    assert pc.peek(lt.key_for(g8)).block == b8    # previous config intact
    assert pc.peek(lt.key_for(g5)).block == b5
    assert lt.tune(g8) == b8 and lt.tune(g5) == b5
    assert pc.stats.misses == 2 and pc.stats.hits == 2
    assert pc.stats.invalidations == 0


def test_live_tuner_device_keyed_isolation():
    """One shared tune cache, two devices with heterogeneous profiles: the
    device id in every key keeps them from serving each other's tiles."""
    pc = PlanCache(32)
    t0 = LiveTuner(CostModel(V100), pc, device_id=0)
    t1 = LiveTuner(CostModel(TPUV5E), pc, device_id=1)
    g = [SA] * 4
    b0, b1 = t0.tune(g), t1.tune(g)
    assert t0.key_for(g) != t1.key_for(g)
    assert pc.stats.misses == 2 and pc.stats.hits == 0
    assert b0 != b1                  # the profiles genuinely tune apart
    # steady state stays per-device
    assert t0.tune(g) == b0 and t1.tune(g) == b1
    assert pc.stats.hits == 2


def test_live_tuner_objective_in_key():
    """Collaborative and greedy results coexist in one cache."""
    pc = PlanCache(32)
    tc = LiveTuner(CM, pc, objective="collaborative")
    tg = LiveTuner(CM, pc, objective="greedy")
    bc, bg = tc.tune(WITNESS), tg.tune(WITNESS)
    assert pc.stats.misses == 2
    assert bc != bg
    assert pc.peek(tc.key_for(WITNESS)).objective == "collaborative"
    assert pc.peek(tg.key_for(WITNESS)).objective == "greedy"


def test_group_signature_is_params_free():
    sig = group_signature([SA, SB])
    assert sig == ((784, 512, 1152, 4, 1), (32, 128, 1152, 4, 1))


# ---------------------------------------------------------------------------
# serving acceptance: identity, steady-state hits, hot-swap immunity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gemma():
    cfg = smoke_config("gemma3-1b")
    m = Model(cfg, param_dtype=jnp.float32)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(gemma, names, **kw):
    m, p = gemma
    return ServingEngine([Tenant(n, m, p, cache_len=64, max_batch=2)
                          for n in names], mode="vliw", **kw)


def _trace(names, steps=6):
    return two_wave_trace(list(names), [], 1e-5, prompt_len=8,
                          max_new_tokens=steps, slo_s=10.0)


def test_engine_live_tune_token_identity_and_hits(gemma):
    names = ["a", "b", "c", "d"]
    base = _engine(gemma, names).run(_trace(names))
    for objective in ("collaborative", "greedy"):
        eng = _engine(gemma, names, live_tune=True, tune_objective=objective)
        rep = eng.run(_trace(names))
        # live tuning retiles dispatches but must not change a single token
        assert _tokens(rep) == _tokens(base)
        st = eng.jit.tune_cache.stats
        # steady state: one search per distinct signature, hits after
        assert st.misses == len(eng.jit.tuner.results) > 0
        assert st.hits > st.misses
        assert eng.jit.tune_cache.stats.invalidations == 0
        # report plumbing: the run's JitStats carry the tune-cache delta
        assert rep.jit.tune_cache.accesses == st.accesses


def test_engine_hot_swap_leaves_tuned_configs_intact(gemma):
    """Tuning keys are shapes-only: a weight hot-swap invalidates block
    plans / packed weights but must not evict or re-tune a single config."""
    m, p = gemma
    eng = _engine(gemma, ["a", "b"], live_tune=True)
    eng.run(_trace(["a", "b"]))
    pc = eng.jit.tune_cache
    before = {k: pc.peek(k).block for k in pc.keys()}
    assert before
    misses0 = pc.stats.misses
    eng.tenants["a"].params = Model(m.cfg, param_dtype=jnp.float32).init(
        jax.random.PRNGKey(7))                       # hot-swap
    eng.run(_trace(["a", "b"]))
    assert pc.stats.invalidations == 0
    assert pc.stats.misses == misses0        # zero re-tunes: all signatures known
    for k, b in before.items():
        assert pc.peek(k).block == b


def test_engine_mesh_tuning_is_device_keyed(gemma):
    names = ["a", "b", "c", "d"]
    eng = _engine(gemma, names, live_tune=True, num_devices=2)
    eng.run(_trace(names, steps=4))
    keys = eng.jit.tune_cache.keys()
    assert keys and all(k[0] == "tune" for k in keys)
    # both mesh devices tuned their own groups under their own key space
    assert {k[1] for k in keys} == {0, 1}
