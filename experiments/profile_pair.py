import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
"""Profile one (arch, shape) dry-run: top tensor shapes by total bytes and
byte/flop census by opcode — the 'profile' step of the §Perf loop.

Usage: PYTHONPATH=src python experiments/profile_pair.py <arch> <shape>
"""
import re
import sys
from collections import Counter, defaultdict

import jax
import jax.numpy as jnp

from repro.launch import hlo_parse as H
from repro.launch.dryrun import dryrun_one  # noqa: F401 (env setup)


def compile_pair(arch, shape_name, multi_pod=False):
    from repro.configs import INPUT_SHAPES, get_config
    from repro.distributed.hints import activation_sharding
    from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                            fsdp_axes, opt_state_shardings,
                                            param_shardings)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import Model
    from repro.training.optimizer import OptimizerConfig, init_opt_state
    from repro.training.train_loop import make_train_step

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg, param_dtype=jnp.bfloat16,
                  remat=(shape.kind == "train"))
    rng = jax.random.PRNGKey(0)
    dp = fsdp_axes(mesh)
    bspec = dp if shape.global_batch % 16 == 0 else None
    hints = {"btd": NamedSharding(mesh, P(bspec, None, None))}
    if cfg.has_moe:
        hints["moe_groups"] = 16
        hints["moe_tokens"] = NamedSharding(mesh, P(dp, None, None))
        if cfg.moe.num_experts % 16 != 0:
            hints["moe_w_col"] = NamedSharding(mesh, P(None, None, "model"))
            hints["moe_w_row"] = NamedSharding(mesh, P(None, "model", None))
            hints["moe_buf"] = NamedSharding(mesh, P(dp, None, None, None))
    with mesh, activation_sharding(hints):
        p_sh = param_shardings(model, mesh, rng)
        p_shape = jax.eval_shape(model.init, rng)
        in_specs = model.input_specs(shape)
        b_sh = batch_shardings(model, shape, mesh)
        if shape.kind == "train":
            opt_sh = opt_state_shardings(p_sh, mesh)
            opt_shape = jax.eval_shape(init_opt_state, p_shape)
            step = make_train_step(model, OptimizerConfig())
            lowered = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                              out_shardings=(p_sh, opt_sh, None),
                              donate_argnums=(0, 1)
                              ).lower(p_shape, opt_shape, in_specs)
        elif shape.kind == "prefill":
            lowered = jax.jit(
                lambda params, batch: model.prefill(
                    params, batch, cache_len=shape.seq_len),
                in_shardings=(p_sh, b_sh)).lower(p_shape, in_specs)
        else:
            c_sh = cache_shardings(model, in_specs["cache"], mesh, shape)
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(p_sh, b_sh["tokens"], c_sh),
                out_shardings=(None, c_sh), donate_argnums=(2,)
            ).lower(p_shape, in_specs["tokens"], in_specs["cache"])
        return lowered.compile()


def census(hlo, min_elems=3e4):
    an = H.HloAnalyzer(hlo)
    shape_bytes = defaultdict(float)   # shape str -> bytes × trips
    opbytes = defaultdict(float)

    def walk(name, in_fusion, mult):
        comp = an.comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if not in_fusion and ins.opcode not in H._FREE_OPS:
                io = an._instr_io_bytes(ins, comp)
                opbytes[ins.opcode] += io * mult
                if ins.result_elems >= min_elems:
                    shape_bytes[ins.result_shape_str.split("{")[0]] += \
                        io * mult
            called = H._CALLED_RE.findall(ins.attrs)
            trip = 1
            if ins.opcode == "while":
                tm = H._TRIP_RE.search(ins.attrs)
                trip = int(tm.group(1)) if tm else 1
            for c in dict.fromkeys(called):
                walk(c, in_fusion or ins.opcode == "fusion", mult * trip)

    walk(an.entry, False, 1.0)
    tot = an.analyze()
    print(f"flops {tot.flops:.3e}  bytes {tot.bytes:.3e}  "
          f"coll {tot.collective_bytes:.3e}")
    print("\ntop result shapes by produced bytes (x trip count):")
    for s, b in sorted(shape_bytes.items(), key=lambda kv: -kv[1])[:12]:
        print(f"  {b:12.3e}  {s}")
    print("\nbytes by opcode:")
    for op, b in sorted(opbytes.items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {b:12.3e}  {op}")


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    compiled = compile_pair(arch, shape)
    census(compiled.as_text())
