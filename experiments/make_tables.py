"""Render the dry-run result JSONs into the EXPERIMENTS.md tables.

Usage: PYTHONPATH=src python experiments/make_tables.py [tag]
"""
import glob
import json
import os
import sys

HERE = os.path.dirname(__file__)


def load(tag=""):
    suffix = f"__{tag}.json" if tag else ".json"
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        base = os.path.basename(f)
        parts = base[:-5].split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) != 3:
            continue
        rows.append(json.load(open(f)))
    return rows


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x:.1e}"
    return f"{x:.{digits}f}"


def roofline_table(rows, mesh="single"):
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL_FLOPS/chip | useful ratio | temp GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        temp = (r["memory"]["temp_bytes"] or 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['compute_s'])} | "
            f"{fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | "
            f"{rf['dominant']} | {rf['model_flops']:.2e} | "
            f"{rf['useful_flops_ratio']:.3f} | {temp:.1f} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | chips | compile s | flops/chip | "
           "bytes/chip | collective B/chip | args GB | temp GB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compile_s']:.1f} | {rf['hlo_flops']:.2e} | "
            f"{rf['hlo_bytes']:.2e} | {rf['coll_bytes']:.2e} | "
            f"{(r['memory']['argument_bytes'] or 0)/1e9:.2f} | "
            f"{(r['memory']['temp_bytes'] or 0)/1e9:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    rows = load(tag)
    print(f"## Roofline (single-pod, 256 chips){f' [{tag}]' if tag else ''}\n")
    print(roofline_table(rows, "single"))
    print(f"\n## Dry-run (all meshes)\n")
    print(dryrun_table(rows))
