"""Paper Fig. 7: GEMM problems across production DNNs concentrate into a few
(n, k) clusters that coalesce with minimal padding. We cluster the full
10-architecture zoo's per-step GEMM population."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import REGISTRY
from repro.core import cluster_greedy, zoo_population


def run() -> None:
    for batch in (1, 8):
        rows = zoo_population(list(REGISTRY.values()), batch=batch)
        shapes = [s for _, _, s in rows]
        clusters = cluster_greedy(shapes, max_waste=0.25)
        big = sorted(clusters, key=lambda c: -len(c.members))[:3]
        derived = ";".join(
            f"cluster{i}[n<={c.pad_n},k<={c.pad_k}]x{len(c.members)}"
            f"@waste{c.padding_waste:.2f}" for i, c in enumerate(big))
        emit(f"fig7/zoo_b{batch}", float(len(clusters)),
             f"problems={len(shapes)};clusters={len(clusters)};{derived}")
        coalescible = sum(len(c.members) for c in clusters
                          if len(c.members) > 1)
        emit(f"fig7/zoo_b{batch}_coalescible",
             100.0 * coalescible / len(shapes),
             f"pct_in_multi_clusters={100.0*coalescible/len(shapes):.0f}%")
