"""MoE coalescing benchmark: non-dense tenants through the JIT (ISSUE 5
acceptance). A heterogeneous fleet — 2 MoE tenants + 2 dense tenants —
decodes concurrently; in vliw mode every tenant's step compiles to a
KernelProgram, so the MoE tenants' per-expert FFN GEMMs enter the live op
pool and coalesce with the other tenants' traffic (the multi-model
spatio-temporal multiplexing scenario D-STACK and the multi-tenant GPU
inference surveys identify as where space-only/time-only sharing loses
most).

Acceptance (checked by ``run()`` / ``main()``; ``--quick`` is the CI smoke
gate — both modes exit nonzero on failure):

  * greedy tokens bit-identical between the vliw and batched engines
    (token divergence fails the run),
  * at least one dispatched superkernel group packs an MoE expert GEMM
    together with ANOTHER tenant's op (``JitStats.expert_coalesced >= 1``;
    zero cross-tenant expert-GEMM coalesced groups fails the run),
  * every MoE/SSM-capable step went through the JIT
    (``JitStats.nondense_programs`` covers all MoE decode steps — the
    monolithic ``_tenant_batched_step`` fallback path fails the run).

Also reports the modeled makespan of both modes and writes the JSON
summary CI uploads as a workflow artifact.

Run:  PYTHONPATH=src python benchmarks/moe_coalescing_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

try:                                     # via the run.py harness
    from benchmarks.common import (emit, header, tuning_summary,
                                   write_summary)
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, tuning_summary, write_summary

from repro.configs import smoke_config
from repro.models import Model
from repro.serving import ServeRequest, ServingEngine, Tenant


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def _tenants():
    out = []
    for name, arch, seed in (("moe-a", "grok-1-314b", 1),
                             ("moe-b", "grok-1-314b", 2),
                             ("dense-a", "gemma3-1b", 3),
                             ("dense-b", "yi-9b", 4)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        out.append(Tenant(name, m, m.init(jax.random.PRNGKey(seed)),
                          cache_len=32, max_batch=2))
    return out


def bench(max_new_tokens: int, n_per_tenant: int):
    names = ["moe-a", "moe-b", "dense-a", "dense-b"]
    trace = [ServeRequest(rid, name, rid * 1e-6, 8, max_new_tokens, 10.0)
             for rid, name in enumerate(
                 n for _ in range(n_per_tenant) for n in names)]
    reps = {}
    for mode in ("batched", "vliw"):
        # the vliw run goes through the per-tick schedule certifier: the
        # MoE expert-GEMM coalescing this bench gates on must be provably
        # hazard-free, not just token-identical
        eng = ServingEngine(_tenants(), mode=mode, certify=(mode == "vliw"))
        reps[mode] = eng.run(trace)
        extra = ""
        if reps[mode].jit:
            j = reps[mode].jit
            extra = (f";expert_coalesced={j.expert_coalesced}"
                     f";nondense_programs={j.nondense_programs}"
                     f";mean_group={j.mean_group:.2f}"
                     f";superkernels={j.superkernels}"
                     f";hazard_checks={j.hazard_checks}"
                     f";hazard_violations={j.hazard_violations}")
        emit(f"moe_coalescing/{mode}/tenants=4",
             reps[mode].modeled_time_s * 1e6,
             f"tok_s={reps[mode].tokens_per_s:.0f}{extra}")
        if mode == "vliw":
            vliw_jit = eng.jit
    return reps, vliw_jit


def check(reps, jit_obj, *, expected_moe_steps: int) -> bool:
    ok = True
    jit = reps["vliw"].jit
    if _tokens(reps["vliw"]) != _tokens(reps["batched"]):
        print("FAIL: vliw greedy tokens diverged from batched mode",
              file=sys.stderr)
        ok = False
    if jit.expert_coalesced < 1:
        print("FAIL: zero superkernel groups coalesced an MoE expert GEMM "
              "with another tenant's op", file=sys.stderr)
        ok = False
    if jit.nondense_programs < expected_moe_steps:
        print(f"FAIL: only {jit.nondense_programs} non-dense steps went "
              f"through the JIT (expected >= {expected_moe_steps}) — the "
              "batched-fallback path is back", file=sys.stderr)
        ok = False
    if jit.hazard_violations != 0 or jit.hazard_checks <= 0:
        print(f"FAIL: schedule certification on the vliw run: "
              f"{jit.hazard_violations} violation(s) over "
              f"{jit.hazard_checks} check(s)", file=sys.stderr)
        ok = False
    write_summary("moe_coalescing", {
        "ok": ok,
        "expert_coalesced": jit.expert_coalesced,
        "nondense_programs": jit.nondense_programs,
        "mean_group": jit.mean_group,
        "superkernels": jit.superkernels,
        "hazard_checks": jit.hazard_checks,
        "hazard_violations": jit.hazard_violations,
        "modeled_time_us_vliw": reps["vliw"].modeled_time_s * 1e6,
        "modeled_time_us_batched": reps["batched"].modeled_time_s * 1e6,
        "tokens_identical":
            _tokens(reps["vliw"]) == _tokens(reps["batched"]),
        "tuning": tuning_summary(jit_obj),
    })
    return ok


def run() -> None:
    """Entry point for the benchmarks/run.py harness."""
    max_new, n_per = 3, 1
    reps, jit_obj = bench(max_new_tokens=max_new, n_per_tenant=n_per)
    # 2 MoE tenants x (max_new - 1) decode steps each
    assert check(reps, jit_obj, expected_moe_steps=2 * (max_new - 1)), \
        "moe coalescing acceptance failed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    args = ap.parse_args()
    max_new = 3 if args.quick else 4
    n_per = 1 if args.quick else 2
    header()
    reps, jit_obj = bench(max_new_tokens=max_new, n_per_tenant=n_per)
    return 0 if check(reps, jit_obj,
                      expected_moe_steps=2 * (max_new - 1)) else 1


if __name__ == "__main__":
    sys.exit(main())
