"""§Roofline: report the dry-run-derived roofline terms for every
(arch × shape) on the single-pod mesh (reads experiments/dryrun/*.json;
run ``python -m repro.launch.dryrun --all`` first)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run() -> None:
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__single.json")))
    if not files:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return
    for f in files:
        r = json.load(open(f))
        rf = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}",
             rf["compute_s"] * 1e6,
             f"memory_s={rf['memory_s']:.3f};coll_s={rf['collective_s']:.3f}"
             f";dominant={rf['dominant']}"
             f";useful={rf['useful_flops_ratio']:.3f}"
             f";chips={rf['chips']}")
