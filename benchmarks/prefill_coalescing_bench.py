"""Prefill-through-the-JIT benchmark: long-prompt multi-tenant serving with
prompt GEMMs declared as first-class ops (ISSUE 3 acceptance).

On a ≥256-token multi-tenant trace, the vliw engine must

  * dispatch at least one superkernel group containing a prefill op
    coalesced with another tenant's op (``JitStats.prefill_coalesced``),
  * keep greedy tokens bit-identical to batched mode, and
  * improve the modeled makespan over BOTH serialized-prefill baselines:
    the per-tenant batched engine and the same vliw engine with
    ``declared_prefill=False`` (the analytic ablation — prefill charged
    serially on the shared clock).

Run:  PYTHONPATH=src python benchmarks/prefill_coalescing_bench.py [--quick]
CI runs ``--quick``: the process exits nonzero if any of the three
properties above fails, so a regression that silently re-serializes
prefill (or breaks token identity) fails CI.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

try:                                     # via the run.py harness
    from benchmarks.common import (emit, header, tuning_summary,
                                   write_summary)
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, tuning_summary, write_summary

from repro.configs import smoke_config
from repro.models import Model
from repro.serving import ServingEngine, Tenant, long_prompt_trace


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def bench(prompt_len: int, max_new_tokens: int, n_per_tenant: int):
    def mk(arch, seed):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        return m, m.init(jax.random.PRNGKey(seed))

    m1, p1 = mk("gemma3-1b", 1)
    m2, p2 = mk("yi-9b", 2)
    cache_len = prompt_len + max_new_tokens + 8

    def tenants():
        return [Tenant("t1", m1, p1, cache_len=cache_len, max_batch=2),
                Tenant("t2", m2, p2, cache_len=cache_len, max_batch=2)]

    trace = long_prompt_trace(["t1", "t2"], prompt_len=prompt_len,
                              max_new_tokens=max_new_tokens,
                              n_per_tenant=n_per_tenant, stagger_s=1e-6)
    reps = {}
    runs = [("batched", dict(mode="batched")),
            ("vliw_serial_prefill", dict(mode="vliw",
                                         declared_prefill=False)),
            ("vliw", dict(mode="vliw"))]
    for name, kw in runs:
        eng = ServingEngine(tenants(), **kw)
        reps[name] = eng.run(trace)
        if name == "vliw":
            vliw_jit = eng.jit
        extra = ""
        if reps[name].jit:
            j = reps[name].jit
            extra = (f";prefill_coalesced={j.prefill_coalesced}"
                     f";mean_group={j.mean_group:.2f}"
                     f";superkernels={j.superkernels}"
                     f";waits={j.waits}")
        emit(f"prefill_coalescing/{name}/prompt={prompt_len}",
             reps[name].modeled_time_s * 1e6,
             f"tok_s={reps[name].tokens_per_s:.0f}"
             f";mean_lat_us={reps[name].mean_latency*1e6:.0f}{extra}")
    speedup_batched = (reps["batched"].modeled_time_s
                       / reps["vliw"].modeled_time_s)
    speedup_serial = (reps["vliw_serial_prefill"].modeled_time_s
                      / reps["vliw"].modeled_time_s)
    emit(f"prefill_coalescing/speedup/prompt={prompt_len}", 0.0,
         f"vs_batched={speedup_batched:.2f}x"
         f";vs_serialized_prefill={speedup_serial:.2f}x")
    return reps, speedup_batched, speedup_serial, vliw_jit


def check(reps, speedup_batched, speedup_serial, jit_obj) -> bool:
    ok = True
    if _tokens(reps["vliw"]) != _tokens(reps["batched"]):
        print("FAIL: vliw greedy tokens diverged from batched mode",
              file=sys.stderr)
        ok = False
    if reps["vliw"].jit.prefill_coalesced < 1:
        print("FAIL: no superkernel group coalesced a prefill op with "
              "another tenant's op", file=sys.stderr)
        ok = False
    if speedup_serial <= 1.0:
        print(f"FAIL: declared prefill does not beat the serialized-"
              f"prefill vliw baseline ({speedup_serial:.3f}x)",
              file=sys.stderr)
        ok = False
    if speedup_batched <= 1.0:
        print(f"FAIL: vliw does not beat the batched baseline "
              f"({speedup_batched:.3f}x)", file=sys.stderr)
        ok = False
    write_summary("prefill_coalescing", {
        "ok": ok,
        "prefill_coalesced": reps["vliw"].jit.prefill_coalesced,
        "speedup_vs_batched": speedup_batched,
        "speedup_vs_serialized_prefill": speedup_serial,
        "tokens_identical": _tokens(reps["vliw"]) == _tokens(reps["batched"]),
        "tuning": tuning_summary(jit_obj),
    })
    return ok


def run() -> None:
    """Entry point for the benchmarks/run.py harness."""
    reps, sb, ss, jit_obj = bench(prompt_len=256, max_new_tokens=3,
                                  n_per_tenant=1)
    assert check(reps, sb, ss, jit_obj), \
        "prefill coalescing acceptance failed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    ap.add_argument("--prompt-len", type=int, default=256)
    args = ap.parse_args()
    # the acceptance claim is about LONG prompts: floor at 256 tokens
    prompt_len = max(args.prompt_len, 256)
    n_per_tenant = 1 if args.quick else 2

    header()
    reps, sb, ss, jit_obj = bench(prompt_len=prompt_len, max_new_tokens=3,
                                  n_per_tenant=n_per_tenant)
    return 0 if check(reps, sb, ss, jit_obj) else 1


if __name__ == "__main__":
    sys.exit(main())
