"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Numbers labelled fig*/table1/rnn_*
reproduce the paper's artifacts via the calibrated V100 device model (plus
real interpret-mode Pallas executions for correctness); roofline/* reads the
TPU-v5e multi-pod dry-run results.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import header
from benchmarks import (compiled_autotune_bench, dispatch_bench,
                        e2e_slo_attainment,
                        fig3_batch_utilization,
                        fig4_time_multiplexing, fig5_spatial_variance,
                        fig6_coalescing, fig7_clustering,
                        moe_coalescing_bench, multi_device_bench,
                        plan_cache_bench,
                        prefill_coalescing_bench, rnn_gemv_coalescing,
                        roofline_report, stacked_depth_bench,
                        table1_autotuning)

MODULES = [
    ("fig3", fig3_batch_utilization),
    ("fig4", fig4_time_multiplexing),
    ("fig5", fig5_spatial_variance),
    ("fig6", fig6_coalescing),
    ("fig7", fig7_clustering),
    ("table1", table1_autotuning),
    ("rnn_gemv", rnn_gemv_coalescing),
    ("roofline", roofline_report),
    ("e2e", e2e_slo_attainment),
    ("plan_cache", plan_cache_bench),
    ("prefill_coalescing", prefill_coalescing_bench),
    ("dispatch", dispatch_bench),
    ("moe_coalescing", moe_coalescing_bench),
    ("stacked_depth", stacked_depth_bench),
    ("multi_device", multi_device_bench),
    ("compiled_autotune", compiled_autotune_bench),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    header()
    failures = []
    for name, mod in MODULES:
        if only and only != name:
            continue
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            failures.append((name, str(e)))
    if failures:
        print(f"# {len(failures)} benchmark module(s) FAILED: {failures}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
