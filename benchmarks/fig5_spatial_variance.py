"""Paper Fig. 5: spatial multiplexing has unpredictable latency — variance
across tenants grows with tenant count and is worse at odd counts. We report
the max/min tenant-latency ratio and SLO misses under the calibrated
contention+jitter model, and the VLIW JIT's behaviour on the same trace."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import (CostModel, V100, make_requests, simulate_space_mux,
                        simulate_vliw)


def run() -> None:
    cm = CostModel(V100)
    cfg = get_config("gemma3-1b")
    for tenants in (2, 3, 4, 5, 8, 9, 10):
        streams = [(cfg, 0.5, [0.0, 1e-3]) for _ in range(tenants)]
        reqs = make_requests(streams, batch=8)
        for name, fn in (("space", simulate_space_mux),
                         ("vliw", simulate_vliw)):
            r = fn(reqs, cm)
            per_stream = {}
            for req in reqs:
                per_stream.setdefault(req.stream_id, []).append(
                    r.latencies[req.req_id])
            means = [float(np.mean(v)) for v in per_stream.values()]
            spread = max(means) / max(min(means), 1e-12)
            emit(f"fig5/{name}/tenants{tenants}", r.mean_latency * 1e6,
                 f"tenant_spread={spread:.3f};slo={r.slo_attainment:.2f}")
