"""Plan-cache microbenchmark: per-step program-build cost on the serving
hot path, cached (template bind) vs uncached (full stage-list rebuild).

The event loop builds one ``KernelProgram`` per tenant per decode step.
Without the plan cache that is a full ``build_dense_decode_template`` —
per-layer param tree_maps plus hundreds of closure allocations — on every
tick of every tenant; with it, steady-state ticks only rebind the per-step
env (tokens, KV cache refs, deadlines). This measures exactly that delta
at >= 8 tenants and reports the speedup.

Run:  PYTHONPATH=src python benchmarks/plan_cache_bench.py [--quick]
CI runs ``--quick`` as a smoke test: the process exits nonzero unless the
cache shows a nonzero hit rate and the cached path is measurably faster,
so a regression that silently reverts to rebuild-per-step fails CI.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

try:                                     # via the run.py harness
    from benchmarks.common import emit, header, write_summary
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, write_summary

from repro.configs import smoke_config
from repro.core.jit import (build_dense_decode_program,
                            build_dense_decode_template,
                            dense_program_cache_key)
from repro.core.plancache import PlanCache


def build_tenants(n_tenants: int, batch: int, cache_len: int):
    """n tenants of one smoke arch: distinct Model objects (distinct cache
    keys), shared params (init once — the build cost under test does not
    depend on the weight values)."""
    from repro.models import Model
    cfg = smoke_config("gemma3-1b")
    params = Model(cfg, param_dtype=jnp.float32).init(jax.random.PRNGKey(0))
    out = []
    for i in range(n_tenants):
        m = Model(cfg, param_dtype=jnp.float32)
        cache = m.init_cache(batch, cache_len)
        tok = jnp.zeros((batch, 1), jnp.int32)
        out.append((m, params, tok, cache))
    return out


def bench(n_tenants: int, steps: int, batch: int = 4, cache_len: int = 32):
    tenants = build_tenants(n_tenants, batch, cache_len)

    # uncached: full rebuild per tenant per step (the old hot path)
    t0 = time.perf_counter()
    for _step in range(steps):
        for sid, (m, params, tok, cache) in enumerate(tenants):
            build_dense_decode_program(m, params, tok, cache, stream_id=sid)
    t_uncached = (time.perf_counter() - t0) / (steps * n_tenants) * 1e6

    # cached: template from the plan cache, bind per step
    cache_obj = PlanCache(capacity=128)
    t0 = time.perf_counter()
    for _step in range(steps):
        for sid, (m, params, tok, kvc) in enumerate(tenants):
            template = cache_obj.get_or_build(
                dense_program_cache_key(m, params, batch, kvc),
                lambda m=m, params=params: build_dense_decode_template(
                    m, params, batch),
                guard=params, group=("tenant", sid))
            template.bind(stream_id=sid, tokens=tok, cache=kvc)
    t_cached = (time.perf_counter() - t0) / (steps * n_tenants) * 1e6

    stats = cache_obj.stats
    speedup = t_uncached / t_cached if t_cached > 0 else float("inf")
    emit(f"program_build_uncached/tenants={n_tenants}", t_uncached,
         f"steps={steps}")
    emit(f"program_build_cached/tenants={n_tenants}", t_cached,
         f"steps={steps};hit_rate={stats.hit_rate:.3f};"
         f"speedup={speedup:.1f}x")
    return stats, speedup


def run() -> None:
    """Entry point for the benchmarks/run.py harness."""
    bench(8, 8)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    ap.add_argument("--tenants", type=int, default=8)
    args = ap.parse_args()
    n_tenants = max(args.tenants, 8)       # the claim is about >= 8 tenants
    steps = 4 if args.quick else 16

    header()
    stats, speedup = bench(n_tenants, steps)

    expect_hits = (steps - 1) * n_tenants  # miss only on each first step
    ok = True
    if stats.hits < expect_hits:
        print(f"FAIL: expected >= {expect_hits} cache hits in steady "
              f"state, got {stats.hits}", file=sys.stderr)
        ok = False
    if stats.hit_rate <= 0.0:
        print("FAIL: plan cache hit rate is zero — the serving hot path "
              "is rebuilding programs per step", file=sys.stderr)
        ok = False
    if speedup <= 1.0:
        print(f"FAIL: cached program build is not faster than rebuild "
              f"(speedup={speedup:.2f}x)", file=sys.stderr)
        ok = False
    write_summary("plan_cache", {
        "ok": ok, "tenants": n_tenants, "steps": steps,
        "hit_rate": stats.hit_rate, "hits": stats.hits,
        "misses": stats.misses, "build_speedup": speedup,
    })
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
