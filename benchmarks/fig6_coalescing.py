"""Paper Fig. 6: coalesced kernels vs space-only vs time-only multiplexing
for the conv2_2 ResNet-18 SGEMM population. Paper: 7.71× over time-slicing,
3.23× over Hyper-Q. Model-derived numbers on V100, plus a REAL
interpret-mode execution of the Pallas superkernel vs serial dispatch to
confirm bit-correct coalesced execution (wall time on CPU is not the claim —
the device model carries the performance argument)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import CostModel, GemmShape, V100
from repro.kernels.ops import execute_superkernel

# conv2_2 of ResNet-18 as SGEMM (paper's kernel): 28x28 output, 128 filters,
# 128x3x3 input patch
CONV2_2 = GemmShape(m=784, n=128, k=1152, dtype_bytes=4)


def run() -> None:
    cm = CostModel(V100)
    for G in (2, 4, 8, 16):
        group = [CONV2_2] * G
        t_coal = cm.coalesced_time(group)
        t_time = cm.time_multiplexed(group)
        t_space = cm.space_multiplexed(group)
        emit(f"fig6/coalesced_G{G}", t_coal * 1e6,
             f"vs_time={t_time/t_coal:.2f}x;vs_space={t_space/t_coal:.2f}x"
             f";paper=7.71x/3.23x")

    # real execution check (interpret-mode Pallas, small replica of conv2_2)
    rng = jax.random.PRNGKey(0)
    probs = []
    for i in range(4):
        ka, kb = jax.random.split(jax.random.fold_in(rng, i))
        probs.append((jax.random.normal(ka, (196, 288), jnp.float32),
                      jax.random.normal(kb, (288, 128), jnp.float32)))
    us_coal = time_jax(lambda: execute_superkernel(probs, bm=64, bn=128,
                                                   bk=96))
    us_serial = time_jax(lambda: [a @ b for a, b in probs])
    err = max(float(jnp.max(jnp.abs(o - a @ b)))
              for (a, b), o in zip(probs,
                                   execute_superkernel(probs, bm=64, bn=128,
                                                       bk=96)))
    emit("fig6/real_superkernel_G4", us_coal,
         f"serial_us={us_serial:.0f};max_err={err:.1e}")
