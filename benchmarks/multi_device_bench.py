"""Multi-device mesh serving benchmark (ISSUE 8 acceptance). A 16-tenant
mixed fleet — dense + expert-parallel MoE + SSM — is served by the vliw
engine on 1, 2 and 4 modeled devices: tenants are bin-packed onto per-device
timelines at admission (distributed/placement.py), ops coalesce only within
a device, MoE tenants span the mesh with their expert weights and pay the
all-to-all dispatch/combine collective, and every run goes through the
per-tick schedule certifier (PlacementHazard taxonomy included).

Acceptance (checked by ``run()`` / ``main()``; ``--quick`` is the CI smoke
gate — both modes exit nonzero on failure):

  * greedy tokens bit-identical across 1, 2 and 4 devices (the mesh is
    modeled: placement must change time attribution, never the math),
  * every device of the 4-device mesh dispatches at least one COALESCED
    group (zero per-device coalesced groups fails the run) and no group
    mixes devices,
  * the modeled makespan improves >= 1.5x from 1 device to 4,
  * zero certifier violations over nonzero checks on every mesh size,
  * nonzero cross-device collective time on the expert-parallel path
    (the MoE all-to-all must be visible, not free).

Also reports per-device utilization / load skew and writes the JSON summary
CI uploads as a workflow artifact.

Run:  PYTHONPATH=src python benchmarks/multi_device_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

try:                                     # via the run.py harness
    from benchmarks.common import (emit, header, tuning_summary,
                                   write_summary)
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, tuning_summary, write_summary

from repro.configs import smoke_config
from repro.models import Model
from repro.serving import ServeRequest, ServingEngine, Tenant

SPEEDUP_FLOOR = 1.5       # required 1-device -> 4-device makespan gain

# 16 tenants over 4 model families: 8 dense, 4 expert-parallel MoE
# (grok smoke has 4 experts — divides mesh sizes 2 and 4), 4 SSM
FLEET = (["gemma3-1b"] * 6 + ["yi-9b"] * 2
         + ["grok-1-314b"] * 4 + ["mamba2-2.7b"] * 4)


def _tenants():
    models = {}
    for seed, arch in enumerate(sorted(set(FLEET))):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        models[arch] = (m, m.init(jax.random.PRNGKey(seed + 1)))
    return [Tenant(f"t{i:02d}", *models[arch], cache_len=32, max_batch=2)
            for i, arch in enumerate(FLEET)]


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def bench(max_new_tokens: int, n_per_tenant: int):
    names = [f"t{i:02d}" for i in range(len(FLEET))]
    # near-simultaneous arrivals: the mesh win is a queueing win, so the
    # fleet must actually saturate one device
    trace = [ServeRequest(rid, name, rid * 1e-7, 8, max_new_tokens, 10.0)
             for rid, name in enumerate(
                 n for _ in range(n_per_tenant) for n in names)]
    runs = {}
    for n_dev in (1, 2, 4):
        eng = ServingEngine(_tenants(), mode="vliw", num_devices=n_dev,
                            certify=True)
        rep = eng.run(trace)
        runs[n_dev] = (rep, eng.last_trace)
        j = rep.jit
        util = ",".join(f"{u:.2f}" for u in rep.device_util)
        emit(f"multi_device/vliw/devices={n_dev}",
             rep.modeled_time_s * 1e6,
             f"tok_s={rep.tokens_per_s:.0f}"
             f";skew={rep.device_skew:.2f};util=[{util}]"
             f";coalesced_groups={j.coalesced_groups}"
             f";collective_us={j.collective_time_s * 1e6:.2f}"
             f";hazard_checks={j.hazard_checks}"
             f";hazard_violations={j.hazard_violations}")
        if n_dev == 4:
            jit4 = eng.jit
    return runs, jit4


def check(runs, jit4) -> bool:
    ok = True
    toks = {n: _tokens(rep) for n, (rep, _) in runs.items()}
    if not (toks[1] == toks[2] == toks[4]):
        print("FAIL: greedy tokens diverged across mesh sizes",
              file=sys.stderr)
        ok = False
    for n_dev, (rep, _) in runs.items():
        j = rep.jit
        if j.hazard_violations != 0 or j.hazard_checks <= 0:
            print(f"FAIL: schedule certification on {n_dev} device(s): "
                  f"{j.hazard_violations} violation(s) over "
                  f"{j.hazard_checks} check(s)", file=sys.stderr)
            ok = False
    rep4, trace4 = runs[4]
    # per-device coalescing: every mesh slot must dispatch at least one
    # multi-op group, and no group may mix devices
    coalesced_by_dev = {d: 0 for d in range(4)}
    for d in trace4.dispatches:
        if any(op.device != d.device for op in d.ops):
            print(f"FAIL: cross-device coalesced group at t={d.t:.6g}",
                  file=sys.stderr)
            ok = False
        if len(d.ops) > 1:
            coalesced_by_dev[d.device] += 1
    empty = [d for d, c in coalesced_by_dev.items() if c == 0]
    if empty:
        print(f"FAIL: zero coalesced groups on device(s) {empty}",
              file=sys.stderr)
        ok = False
    speedup = (runs[1][0].modeled_time_s / rep4.modeled_time_s
               if rep4.modeled_time_s else 0.0)
    if speedup < SPEEDUP_FLOOR:
        print(f"FAIL: 1->4 device makespan speedup {speedup:.2f}x "
              f"< {SPEEDUP_FLOOR}x", file=sys.stderr)
        ok = False
    if rep4.jit.collective_time_s <= 0.0:
        print("FAIL: expert-parallel MoE tenants paid zero cross-device "
              "collective time — the all-to-all charge is not wired",
              file=sys.stderr)
        ok = False
    write_summary("multi_device", {
        "ok": ok,
        "tokens_identical": toks[1] == toks[2] == toks[4],
        "speedup_1_to_4": speedup,
        "speedup_floor": SPEEDUP_FLOOR,
        "coalesced_groups_by_device": coalesced_by_dev,
        "collective_time_us_4dev": rep4.jit.collective_time_s * 1e6,
        **{f"modeled_time_us_{n}dev": rep.modeled_time_s * 1e6
           for n, (rep, _) in runs.items()},
        **{f"device_skew_{n}dev": rep.device_skew
           for n, (rep, _) in runs.items()},
        "device_util_4dev": rep4.device_util,
        "hazard_checks": rep4.jit.hazard_checks,
        "hazard_violations": rep4.jit.hazard_violations,
        "tuning": tuning_summary(jit4),
    })
    return ok


def run() -> None:
    """Entry point for the benchmarks/run.py harness."""
    runs, jit4 = bench(max_new_tokens=3, n_per_tenant=1)
    assert check(runs, jit4), "multi-device mesh acceptance failed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    args = ap.parse_args()
    max_new = 3 if args.quick else 4
    n_per = 1 if args.quick else 2
    header()
    runs, jit4 = bench(max_new_tokens=max_new, n_per_tenant=n_per)
    return 0 if check(runs, jit4) else 1


if __name__ == "__main__":
    sys.exit(main())
