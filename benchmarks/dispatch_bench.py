"""Dispatch fast-path benchmark: WALL-CLOCK per-tick dispatch cost, cached
(SuperkernelExecutor: persistent packed weights + bucketed jitted
pack/kernel/unpack) vs uncached (eager ``execute_superkernel``), on stable
and churning group shapes (ISSUE 4 acceptance).

Every other benchmark in this suite reports *modeled* device time; this one
times the host dispatch itself — the thing the executor exists to retire.
The eager path re-pads and re-stacks the group's full weight matrices on
every tick (O(model-weights) host traffic) and runs pack → kernel → unpack
as separate eager ops; the cached path re-sends zero weight bytes in steady
state and dispatches one compiled executable.

Acceptance (checked by ``run()`` / ``main()``; ``--quick`` is the CI smoke
gate):

  * steady state at 8 dense-decode tenants: cached path ≥ 3x faster per
    tick (full mode; ``--quick`` requires any speedup > 1x),
  * weight-pack cache hit rate ≥ (steps-1)/steps on the stable trace,
  * zero post-warmup retraces on the stable trace,
  * greedy tokens bit-identical between the cached and eager engine runs.

Run:  PYTHONPATH=src python benchmarks/dispatch_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

try:                                     # via the run.py harness
    from benchmarks.common import (emit, header, tuning_summary,
                                   write_summary)
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, tuning_summary, write_summary

from repro.configs import smoke_config
from repro.core import GemmShape, make_op
from repro.core.dispatch import SuperkernelExecutor
from repro.core.plancache import PlanCache
from repro.kernels.ops import execute_superkernel
from repro.models import Model
from repro.serving import ServingEngine, Tenant, two_wave_trace


def _problems(n_tenants: int, m: int, k: int, n: int):
    """One coalesced decode group: n_tenants same-shape GEMV-aspect
    problems with DISTINCT weights (the cross-tenant case — nothing to
    operand-share, the full weight stack moves on every eager dispatch)."""
    probs, keys = [], []
    for i in range(n_tenants):
        a = jax.random.normal(jax.random.PRNGKey(2 * i), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(2 * i + 1), (k, n),
                              jnp.float32)
        probs.append((a, w))
        keys.append(("tenant", i, "ffn"))
    return probs, keys


def _ops(probs, keys):
    ops = []
    for i, ((a, w), key) in enumerate(zip(probs, keys)):
        op = make_op(i, "gemv", GemmShape(m=int(a.shape[0]),
                                          n=int(w.shape[1]),
                                          k=int(w.shape[0])))
        op.payload = (a, w, key)
        ops.append(op)
    return ops


def _time_ticks(fn, groups, steps: int) -> float:
    """Mean wall-clock microseconds per dispatch over ``steps`` ticks,
    cycling through ``groups`` (len 1 = the stable trace)."""
    jax.block_until_ready(fn(groups[0]))          # warmup outside the clock
    t0 = time.perf_counter()
    for s in range(steps):
        out = fn(groups[s % len(groups)])
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / steps * 1e6


def bench_dispatch(n_tenants: int, steps: int, m: int = 4, k: int = 128,
                   n: int = 128):
    """Per-tick dispatch cost at the smoke-model decode regime (m=4 rows
    against d_model-sized weights — what the serving engine's steady-state
    tick actually dispatches). In interpret mode the Pallas kernel itself
    is artificially expensive relative to a real TPU, so larger envelopes
    (k=512+) understate the dispatch-layer win; the k=512 context row below
    is emitted unguarded for reference."""
    probs, keys = _problems(n_tenants, m, k, n)
    full = _ops(probs, keys)
    # churn trace: the group composition cycles (tenants drop in and out),
    # exercising the envelope buckets instead of one fixed signature
    churn_sizes = [n_tenants, n_tenants - 1, n_tenants - 2, n_tenants - 3]
    churn_groups = [full[:g] for g in churn_sizes]

    results = {}
    for trace_name, groups in (("stable", [full]), ("churn", churn_groups)):
        t_eager = _time_ticks(
            lambda ops: execute_superkernel([o.payload[:2] for o in ops],
                                            bm=8),
            groups, steps)
        ex = SuperkernelExecutor(PlanCache(64), bm=8)
        ex.execute(groups[0])                      # warm cache + traces
        warm_retraces = ex.stats.retraces
        stats0 = ex.stats.copy()
        t_cached = _time_ticks(lambda ops, ex=ex: ex.execute(ops),
                               groups, steps)
        d = ex.stats - stats0
        speedup = t_eager / t_cached if t_cached > 0 else float("inf")
        results[trace_name] = (speedup, d, ex.stats.retraces - warm_retraces)
        emit(f"dispatch/{trace_name}/eager/tenants={n_tenants}", t_eager,
             f"steps={steps};m={m};k={k};n={n}")
        emit(f"dispatch/{trace_name}/cached/tenants={n_tenants}", t_cached,
             f"steps={steps};speedup={speedup:.1f}x"
             f";weight_hit_rate={d.weight_hit_rate:.3f}"
             f";post_warmup_retraces={ex.stats.retraces - warm_retraces}"
             f";MB_not_copied={d.bytes_not_copied / 1e6:.0f}")
    return results


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def bench_serving_identity(max_new_tokens: int):
    """End-to-end gate: the cached dispatch path must emit bit-identical
    greedy tokens to the eager reference on a real two-tenant serve. Both
    runs go through the per-tick schedule certifier (certify=True) — every
    OoO reordering on this path must be provably hazard-free."""
    def mk(arch, seed):
        cfg = smoke_config(arch)
        mdl = Model(cfg, param_dtype=jnp.float32)
        return mdl, mdl.init(jax.random.PRNGKey(seed))

    m1, p1 = mk("gemma3-1b", 1)
    m2, p2 = mk("yi-9b", 2)
    trace = two_wave_trace(["a"], ["b"], 1e-5, prompt_len=8,
                           max_new_tokens=max_new_tokens, slo_s=1.0)
    reps = {}
    for name, enabled in (("eager", False), ("cached", True)):
        eng = ServingEngine(
            [Tenant("a", m1, p1, cache_len=32, max_batch=2),
             Tenant("b", m2, p2, cache_len=32, max_batch=2)], mode="vliw",
            certify=True)
        eng.jit.executor.enabled = enabled
        reps[name] = eng.run(trace)
    hit_rate = reps["cached"].jit.dispatch.weight_hit_rate
    jit = reps["cached"].jit.merge(reps["eager"].jit)
    emit("dispatch/serving_identity",
         reps["cached"].wall_time_s * 1e6,
         f"tokens_identical={_tokens(reps['eager']) == _tokens(reps['cached'])}"
         f";weight_hit_rate={hit_rate:.3f}"
         f";hazard_checks={jit.hazard_checks}"
         f";hazard_violations={jit.hazard_violations}")
    return (_tokens(reps["eager"]) == _tokens(reps["cached"]),
            jit.hazard_checks, jit.hazard_violations, eng.jit)


def check(results, serving, steps: int, *,
          min_speedup: float) -> bool:
    ok = True
    tokens_ok, hazard_checks, hazard_violations, jit_obj = serving
    speedup, d, retraces = results["stable"]
    if speedup < min_speedup:
        print(f"FAIL: cached dispatch not >= {min_speedup:.1f}x faster than "
              f"the eager path in steady state ({speedup:.2f}x)",
              file=sys.stderr)
        ok = False
    hits_needed = (steps - 1) / steps
    if d.weight_hit_rate < hits_needed:
        print(f"FAIL: weight-pack hit rate {d.weight_hit_rate:.3f} < "
              f"(steps-1)/steps = {hits_needed:.3f}", file=sys.stderr)
        ok = False
    if retraces != 0:
        print(f"FAIL: {retraces} post-warmup retraces on the stable trace",
              file=sys.stderr)
        ok = False
    if not tokens_ok:
        print("FAIL: cached dispatch changed greedy tokens vs the eager "
              "reference", file=sys.stderr)
        ok = False
    # the serving runs went through the per-tick certifier: a clean pass
    # means zero violations AND a nonzero number of evaluated predicates
    # (a certifier that checked nothing must not read as a pass)
    if hazard_violations != 0 or hazard_checks <= 0:
        print(f"FAIL: schedule certification on the serving runs: "
              f"{hazard_violations} violation(s) over {hazard_checks} "
              f"check(s)", file=sys.stderr)
        ok = False
    write_summary("dispatch", {
        "ok": ok, "steps": steps, "stable_speedup": speedup,
        "weight_hit_rate": d.weight_hit_rate,
        "bytes_not_copied": d.bytes_not_copied,
        "post_warmup_retraces": retraces, "tokens_identical": tokens_ok,
        "hazard_checks": hazard_checks,
        "hazard_violations": hazard_violations,
        "tuning": tuning_summary(jit_obj),
    })
    return ok


def run() -> None:
    """Entry point for the benchmarks/run.py harness (full acceptance)."""
    results = bench_dispatch(8, steps=16)
    bench_dispatch(8, steps=8, k=512, n=512)       # context row, ungated
    serving = bench_serving_identity(3)
    assert check(results, serving, 16, min_speedup=3.0), \
        "dispatch fast-path acceptance failed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    ap.add_argument("--tenants", type=int, default=8)
    args = ap.parse_args()
    if args.tenants < 8:                   # the claim is about >= 8 tenants
        ap.error("--tenants must be >= 8 (the acceptance claim is about "
                 "steady-state dispatch at >= 8 dense tenants)")
    n_tenants = args.tenants
    steps = 8 if args.quick else 32

    header()
    results = bench_dispatch(n_tenants, steps)
    if not args.quick:
        bench_dispatch(n_tenants, steps=8, k=512, n=512)  # context, ungated
    serving = bench_serving_identity(4 if args.quick else 6)
    # --quick (CI) gates on ANY wall-clock speedup so host jitter cannot
    # flake the build; the full run enforces the >= 3x acceptance claim
    return 0 if check(results, serving, steps,
                      min_speedup=1.0 if args.quick else 3.0) else 1


if __name__ == "__main__":
    sys.exit(main())
