"""Paper Fig. 3: the utilization gap — single-model inference at interactive
batch sizes cannot saturate the device. We evaluate a ResNet-50-like GEMM
population (im2col'd convs, m scales with batch) plus our transformer decode
population on the calibrated V100 model and the TPU-v5e target."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import CostModel, GemmShape, TPUV5E, V100
from repro.core.kernelspec import gemm_population

# representative ResNet-50 conv GEMMs at batch 1 (im2col):
# (m = H*W, n = Cout, k = Cin*kh*kw)
RESNET50_GEMMS = [
    (3136, 64, 576), (3136, 64, 64), (3136, 256, 64),
    (784, 128, 1152), (784, 512, 128), (196, 256, 2304),
    (196, 1024, 256), (49, 512, 4608), (49, 2048, 512),
]


def run() -> None:
    for device in (V100, TPUV5E):
        cm = CostModel(device)
        dtype_bytes = 4 if device.name == "v100" else 2
        for batch in (1, 2, 4, 8, 16, 32, 64):
            shapes = [GemmShape(m * batch, n, k, dtype_bytes)
                      for m, n, k in RESNET50_GEMMS]
            t = sum(cm.gemm_time(s) for s in shapes)
            util = cm.utilization(shapes, t)
            emit(f"fig3/{device.name}/resnet50_b{batch}", t * 1e6,
                 f"util={util:.3f}")
        # transformer decode population (gemma3) at decode batch sizes
        cfg = get_config("gemma3-1b")
        for batch in (1, 8, 64, 256):
            pop = [s for tag, s in gemm_population(cfg, batch)
                   if tag != "unembed"]
            t = sum(cm.gemm_time(s) for s in pop) * cfg.num_layers
            util = cm.utilization(pop * cfg.num_layers, t)
            emit(f"fig3/{device.name}/gemma3_decode_b{batch}", t * 1e6,
                 f"util={util:.3f}")
