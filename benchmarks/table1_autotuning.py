"""Paper Table 1: greedy vs collaborative autotuned kernels. Greedy
maximizes isolated throughput; collaborative accepts an isolated regression
for higher aggregate throughput when dispatched concurrently (paper: 1.25×,
20% isolated regression)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import Autotuner, CostModel, GemmShape, V100


def run() -> None:
    cm = CostModel(V100)
    at = Autotuner(cm)
    shape = GemmShape(m=784, n=512, k=1152, dtype_bytes=4)
    for K in (2, 3, 4):
        r = at.tune(shape, co_tenants=K)
        g_iso = cm.achieved_tflops([shape], r.greedy_isolated_s)
        c_iso = cm.achieved_tflops([shape], r.collab_isolated_s)
        g_mux = cm.achieved_tflops([shape] * K, r.greedy_multiplexed_s)
        c_mux = cm.achieved_tflops([shape] * K, r.collab_multiplexed_s)
        emit(f"table1/K{K}", r.collab_multiplexed_s * 1e6,
             f"greedy_iso={g_iso:.2f}TF;collab_iso={c_iso:.2f}TF;"
             f"greedy_mux={g_mux:.2f}TF;collab_mux={c_mux:.2f}TF;"
             f"speedup={r.multiplexed_speedup:.2f}x(paper1.25x);"
             f"iso_regression={r.isolated_regression:.2f}")
