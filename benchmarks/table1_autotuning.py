"""Paper Table 1: greedy vs collaborative autotuned kernels. Greedy
maximizes isolated throughput; collaborative accepts an isolated regression
for higher aggregate throughput when dispatched concurrently (paper: 1.25×,
20% isolated regression).

``--live`` additionally cross-checks the LIVE tuner (the one the JIT
consults on the dispatch hot path, core/autotuner.LiveTuner) against the
offline autotuner: on a STABLE uniform group both faces minimize the same
collaborative objective over the same candidate set, so their tuned
(bm, bn, bk) must agree exactly — and the second live lookup must be a
pure tune-cache hit. The run.py harness runs both parts.
"""
from __future__ import annotations

import argparse
import sys

try:                                     # via the run.py harness
    from benchmarks.common import emit, header
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header

from repro.core import (Autotuner, CostModel, GemmShape, LiveTuner,
                        PlanCache, V100)


def run_offline() -> None:
    cm = CostModel(V100)
    at = Autotuner(cm)
    shape = GemmShape(m=784, n=512, k=1152, dtype_bytes=4)
    for K in (2, 3, 4):
        r = at.tune(shape, co_tenants=K)
        g_iso = cm.achieved_tflops([shape], r.greedy_isolated_s)
        c_iso = cm.achieved_tflops([shape], r.collab_isolated_s)
        g_mux = cm.achieved_tflops([shape] * K, r.greedy_multiplexed_s)
        c_mux = cm.achieved_tflops([shape] * K, r.collab_multiplexed_s)
        emit(f"table1/K{K}", r.collab_multiplexed_s * 1e6,
             f"greedy_iso={g_iso:.2f}TF;collab_iso={c_iso:.2f}TF;"
             f"greedy_mux={g_mux:.2f}TF;collab_mux={c_mux:.2f}TF;"
             f"speedup={r.multiplexed_speedup:.2f}x(paper1.25x);"
             f"iso_regression={r.isolated_regression:.2f}")


def run_live() -> bool:
    """Offline-tuned vs live-tuned configs must agree on stable groups."""
    cm = CostModel(V100)
    at = Autotuner(cm)
    lt = LiveTuner(cm, PlanCache(32))       # collaborative objective
    ok = True
    cases = [(GemmShape(784, 512, 1152, dtype_bytes=4), 4),
             (GemmShape(16, 2048, 2048, dtype_bytes=4), 8),
             (GemmShape(1, 4096, 2048, dtype_bytes=4), 6)]
    for shape, G in cases:
        offline = at.tune_for_coalescing(shape, G)
        group = [shape] * G
        live = lt.tune(group)
        agree = offline == live
        hit = lt.tune(group) == live and lt.cache.stats.hits >= 1
        emit(f"table1/live/G{G}", 0.0,
             f"m={shape.m};n={shape.n};k={shape.k}"
             f";offline={offline.bm}x{offline.bn}x{offline.bk}"
             f";live={live.bm}x{live.bn}x{live.bk}"
             f";agree={agree};steady_hit={hit}")
        if not (agree and hit):
            print(f"FAIL: live tuner diverged from offline on stable "
                  f"group {shape} x{G}: offline={offline} live={live} "
                  f"steady_hit={hit}", file=sys.stderr)
            ok = False
    return ok


def run() -> None:
    """Entry point for the benchmarks/run.py harness (both parts)."""
    run_offline()
    assert run_live(), "live vs offline autotuner agreement failed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--live", action="store_true",
                    help="run only the live-vs-offline agreement check")
    args = ap.parse_args()
    header()
    if args.live:
        return 0 if run_live() else 1
    run_offline()
    return 0 if run_live() else 1


if __name__ == "__main__":
    sys.exit(main())
