"""Paper Fig. 4: mean latency of 1..15 replicas of one model on a V100 —
time multiplexing degrades linearly; batched inference is far cheaper; the
VLIW JIT closes most of the gap."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import (CostModel, V100, make_requests, simulate_space_mux,
                        simulate_time_mux, simulate_vliw)


def run() -> None:
    cm = CostModel(V100)
    cfg = get_config("internvl2-2b")  # a ResNet-50-scale compute budget
    for replicas in (1, 2, 4, 8, 15):
        streams = [(cfg, 10.0, [0.0, 1e-4, 2e-4]) for _ in range(replicas)]
        reqs = make_requests(streams, batch=8)
        for name, fn in (("time", simulate_time_mux),
                         ("space", simulate_space_mux),
                         ("vliw", simulate_vliw)):
            r = fn(reqs, cm)
            emit(f"fig4/{name}/replicas{replicas}",
                 r.mean_latency * 1e6,
                 f"p99_ms={r.p(0.99)*1e3:.2f};util={r.utilization:.3f}")
