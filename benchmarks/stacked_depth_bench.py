"""Stacked-template depth scaling: build time and trace size vs num_layers.

The tentpole claim of the scan-over-layers refactor: template BUILD cost
(and the emitted stage count, a proxy for JIT trace size) is O(1) in model
depth for the stacked regime, while the per-layer oracle emission grows
linearly. Measured on a granite-34b-shaped dense config at smoke dims with
depth swept over 4 / 16 / 48 layers.

Run:  PYTHONPATH=src python benchmarks/stacked_depth_bench.py [--quick]
CI runs ``--quick`` as a smoke test: the process exits nonzero unless
  * stacked build time grows < 1.5x from 4 to 48 layers while the
    per-layer build grows >= 5x (the O(1)-vs-O(L) separation), and
  * a 4-layer config decodes greedy tokens BIT-identically through the
    stacked and per-layer template paths (the correctness gate).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

try:                                     # via the run.py harness
    from benchmarks.common import (emit, header, tuning_summary,
                                   write_summary)
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, tuning_summary, write_summary

from repro.configs import smoke_config
from repro.core.jit import VLIWJit, build_dense_decode_template
from repro.models import Model

DEPTHS = (4, 16, 48)


def _model_at_depth(L: int):
    cfg = dataclasses.replace(smoke_config("granite-34b"), num_layers=L)
    m = Model(cfg, param_dtype=jnp.float32)
    return m, m.init(jax.random.PRNGKey(0))


def bench(reps: int):
    """Per depth: min-of-reps template build time (us) + stage count for
    both regimes. Returns {depth: {regime: (us, n_stages)}}."""
    out = {}
    for L in DEPTHS:
        m, params = _model_at_depth(L)
        out[L] = {}
        for regime, stacked in (("stacked", True), ("per_layer", False)):
            best = float("inf")
            tmpl = None
            for _ in range(reps):
                t0 = time.perf_counter()
                tmpl = build_dense_decode_template(m, params, 2,
                                                   stacked=stacked)
                best = min(best, (time.perf_counter() - t0) * 1e6)
            n_stages = len(tmpl.stages)
            out[L][regime] = (best, n_stages)
            emit(f"template_build/{regime}/L={L}", best,
                 f"stages={n_stages}")
    return out


def check_token_identity() -> bool:
    """4-layer greedy decode: stacked vs per-layer tokens must be
    bit-identical (they compare equal logits bit-for-bit upstream; the
    token check here is the cheap end-to-end gate)."""
    m, params = _model_at_depth(4)
    cfg = m.cfg
    rng = jax.random.PRNGKey(1)
    _, cache0 = m.prefill(params, {"tokens": jax.random.randint(
        rng, (2, 6), 0, cfg.vocab_size)}, cache_len=32)
    tok0 = jax.random.randint(jax.random.fold_in(rng, 7), (2, 1), 0,
                              cfg.vocab_size)
    toks = {}
    for stacked in (True, False):
        tmpl = build_dense_decode_template(m, params, 2, stacked=stacked)
        vj = VLIWJit(max_group=8)
        cache, tok, seq = cache0, tok0, []
        for _ in range(3):
            prog = tmpl.bind(stream_id=0, tokens=tok, cache=cache)
            vj.run([prog])
            tok = jnp.argmax(prog.env["logits"],
                             axis=-1).astype(jnp.int32)[:, None]
            cache = prog.env["cache"]
            seq.append(np.asarray(tok).ravel().tolist())
        toks[stacked] = seq
        if stacked:
            stacked_jit = vj
    return toks[True] == toks[False], stacked_jit


def run() -> None:
    """Entry point for the benchmarks/run.py harness."""
    bench(reps=3)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    args = ap.parse_args()
    reps = 2 if args.quick else 5

    header()
    results = bench(reps)
    lo, hi = DEPTHS[0], DEPTHS[-1]
    stacked_growth = results[hi]["stacked"][0] / results[lo]["stacked"][0]
    per_layer_growth = (results[hi]["per_layer"][0]
                        / results[lo]["per_layer"][0])
    stacked_stage_growth = (results[hi]["stacked"][1]
                            / results[lo]["stacked"][1])
    emit(f"build_growth/stacked/{lo}->{hi}", 0.0,
         f"ratio={stacked_growth:.2f}x")
    emit(f"build_growth/per_layer/{lo}->{hi}", 0.0,
         f"ratio={per_layer_growth:.2f}x")

    ok = True
    if stacked_growth >= 1.5:
        print(f"FAIL: stacked template build grew {stacked_growth:.2f}x "
              f"from {lo} to {hi} layers (must stay < 1.5x — the O(1)-in-"
              "depth contract)", file=sys.stderr)
        ok = False
    if per_layer_growth < 5.0:
        print(f"FAIL: per-layer build grew only {per_layer_growth:.2f}x "
              f"from {lo} to {hi} layers (expected >= 5x — is the oracle "
              "path still emitting per layer?)", file=sys.stderr)
        ok = False
    if stacked_stage_growth != 1.0:
        print(f"FAIL: stacked stage count grew {stacked_stage_growth:.2f}x "
              "with depth (trace size must be depth-independent)",
              file=sys.stderr)
        ok = False
    tokens_ok, stacked_jit = check_token_identity()
    if not tokens_ok:
        print("FAIL: stacked vs per-layer greedy tokens DIVERGED",
              file=sys.stderr)
        ok = False

    write_summary("stacked_depth", {
        "ok": ok, "depths": list(DEPTHS),
        "stacked_build_us": {L: results[L]["stacked"][0] for L in DEPTHS},
        "per_layer_build_us": {L: results[L]["per_layer"][0]
                               for L in DEPTHS},
        "stacked_stages": {L: results[L]["stacked"][1] for L in DEPTHS},
        "per_layer_stages": {L: results[L]["per_layer"][1]
                             for L in DEPTHS},
        "stacked_build_growth": stacked_growth,
        "per_layer_build_growth": per_layer_growth,
        "token_identity": tokens_ok,
        "tuning": tuning_summary(stacked_jit),
    })
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
