"""End-to-end §5.2 + the serving front door (ISSUE 10 acceptance).

Part 1 (seed): multi-tenant serving with SLOs under the three engine modes
(time-multiplexed, per-tenant batched, VLIW JIT). Real token generation
through reduced models; time attributed by the TPU-v5e device model.
Greedy tokens must agree across modes.

Part 2 (front door): SLO attainment and goodput vs offered load. An
open-loop tiered trace is served at three load levels — under, at and far
past the saturation knee (multiples of the analytic per-request cost) —
once with SLO-tiered admission control at the door (admit / degrade /
shed from the cost model + arrival forecast) and once with the
admit-everything ablation.

Acceptance (checked by ``run()`` / ``main()``; ``--quick`` is the CI smoke
gate — both modes exit nonzero on failure):

  * tokens bit-identical across the three engine modes (seed gate),
  * past the knee, admission control beats admit-everything on goodput
    AND on overall + per-tier SLO attainment (the loosest/batch rung —
    the door's designated degrade/shed sacrifice tier — is allowed a
    small bounded dip); far past the knee the door must shed,
  * shed requests are counted as SLO misses in reported attainment
    (never silently dropped from the denominator),
  * tokens bit-identical on the admitted set: admission changes WHO runs,
    never the math of what runs,
  * the daemon loop (``serve_forever`` on a follower VirtualClock, door
    pre-scheduled with the same trace) reproduces the replay run
    bit-identically — same tokens, same shed set.

Also writes the JSON summary CI uploads as a workflow artifact.

Run:  PYTHONPATH=src python benchmarks/e2e_slo_attainment.py [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax
import jax.numpy as jnp

try:                                     # via the run.py harness
    from benchmarks.common import emit, header, write_summary
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, write_summary

from repro.configs import smoke_config
from repro.models import Model
from repro.serving import (FrontDoor, ServeRequest, ServingEngine, Tenant,
                           VirtualClock, make_trace, open_loop_trace)

# offered load as multiples of the modeled per-request service rate:
# comfortably under the knee, around it, and far past it
LOAD_LEVELS = (0.5, 2.0, 8.0)
KNEE = 1.0          # levels strictly above this must show dominance


def _models():
    models = {}
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        models[arch] = (m, m.init(jax.random.PRNGKey(seed)))
    return models


def _tenants(models):
    return [Tenant("t1", *models["gemma3-1b"], cache_len=32, max_batch=4),
            Tenant("t2", *models["yi-9b"], cache_len=32, max_batch=4)]


def _tokens(rep):
    return {r.req_id: tuple(r.tokens_out or ()) for r in rep.requests}


def bench_modes(models):
    """Seed section: the three engine modes on one SLO trace."""
    trace = make_trace(["t1", "t2"], rate_hz=1e5, n_per_tenant=3,
                       prompt_len=8, max_new_tokens=4, slo_s=0.002)
    tokens = {}
    for mode in ("time", "batched", "vliw"):
        eng = ServingEngine(_tenants(models), mode=mode)
        rep = eng.run(trace)
        tokens[mode] = [r.tokens_out for r in
                        sorted(rep.requests, key=lambda r: r.req_id)]
        extra = ""
        if rep.jit:
            extra = (f";mean_group={rep.jit.mean_group:.2f}"
                     f";superkernels={rep.jit.superkernels}"
                     f";modeled_speedup={rep.jit.modeled_speedup:.2f}x"
                     f";waits={rep.jit.waits}"
                     f";evictions={rep.jit.evictions}"
                     f";mid_flight={rep.jit.mid_flight_admissions}")
        emit(f"e2e/{mode}", rep.modeled_time_s * 1e6,
             f"mean_lat_us={rep.mean_latency*1e6:.0f}"
             f";p99_us={rep.p_latency(0.99)*1e6:.0f}"
             f";slo={rep.slo_attainment:.2f}"
             f";tok_s={rep.tokens_per_s:.0f}{extra}")
    return tokens


def bench_front_door(models, n_requests: int):
    """Front-door section: attainment/goodput vs offered load, admission
    control vs the admit-everything ablation, plus the daemon-equals-
    replay check at the top load level."""
    probe = ServingEngine(_tenants(models), mode="vliw")
    cost = probe._request_cost_s(
        probe.tenants["t1"], ServeRequest(0, "t1", 0.0, 8, 2, 1.0))
    # tier SLOs in units of the modeled per-request cost: a tight
    # interactive rung, a standard rung, and a wide batch rung — wide
    # enough that requests the door degrades into it can still retire
    # inside their (relaxed) deadline
    tiers = (4 * cost, 10 * cost, 30 * cost)
    sweep = {}
    for mult in LOAD_LEVELS:
        trace = open_loop_trace(
            ["t1", "t2"], rate_hz=mult / cost, n=n_requests,
            shape="poisson", tier_slo_s=tiers, prompt_len=8,
            max_new_tokens=2, seed=7)
        reps = {}
        for policy, admit in (("admission", True), ("admit_all", False)):
            eng = ServingEngine(_tenants(models), mode="vliw",
                                admission_control=admit)
            rep = eng.run(trace)
            reps[policy] = rep
            by_tier = ";".join(
                f"tier{t}={a:.2f}"
                for t, a in rep.tier_attainment().items())
            emit(f"e2e_slo/load={mult:g}x/{policy}",
                 rep.modeled_time_s * 1e6,
                 f"slo={rep.slo_attainment:.2f}"
                 f";goodput_rps={rep.goodput_rps:.0f}"
                 f";shed={rep.shed};unfinished={rep.unfinished}"
                 f";p99_us={rep.p_latency(0.99)*1e6:.0f};{by_tier}")
        sweep[mult] = (trace, reps)

    # daemon-equals-replay at the top load level: pre-scheduled door on a
    # follower VirtualClock through the SAME admission controller
    top = max(LOAD_LEVELS)
    trace, reps = sweep[top]
    eng = ServingEngine(_tenants(models), mode="vliw",
                        admission_control=True)
    door = FrontDoor()
    for r in trace:
        door.submit(dataclasses.replace(r), at=r.arrival_t)
    door.close(at=max(r.arrival_t for r in trace))
    rep_daemon = eng.serve_forever(door, clock=VirtualClock())
    emit(f"e2e_slo/daemon/load={top:g}x", rep_daemon.modeled_time_s * 1e6,
         f"slo={rep_daemon.slo_attainment:.2f}"
         f";goodput_rps={rep_daemon.goodput_rps:.0f}"
         f";shed={rep_daemon.shed}")
    return sweep, rep_daemon


def check(mode_tokens, sweep, rep_daemon) -> bool:
    ok = True
    if not (mode_tokens["time"] == mode_tokens["batched"]
            == mode_tokens["vliw"]):
        print("FAIL: greedy tokens diverged across engine modes",
              file=sys.stderr)
        ok = False
    past_knee = [m for m in sweep if m > KNEE]
    for mult in past_knee:
        _, reps = sweep[mult]
        ctl, all_ = reps["admission"], reps["admit_all"]
        if not (ctl.goodput_rps > all_.goodput_rps):
            print(f"FAIL: load={mult}x goodput inversion: admission "
                  f"{ctl.goodput_rps:.0f} <= admit-all "
                  f"{all_.goodput_rps:.0f} rps", file=sys.stderr)
            ok = False
        if not (ctl.slo_attainment > all_.slo_attainment):
            print(f"FAIL: load={mult}x attainment inversion: admission "
                  f"{ctl.slo_attainment:.2f} <= admit-all "
                  f"{all_.slo_attainment:.2f}", file=sys.stderr)
            ok = False
        # per-tier dominance (original-tier grouping: the door's ledger).
        # The loosest rung is the door's designated sacrifice tier — it
        # absorbs degraded traffic and sheds first — so it is allowed a
        # bounded dip; every tighter tier must show no inversion.
        t_ctl, t_all = ctl.tier_attainment(), all_.tier_attainment()
        loosest = max(t_all)
        for tier in t_all:
            slack = 0.25 if tier == loosest else 0.0
            if t_ctl.get(tier, 0.0) < t_all[tier] - slack:
                print(f"FAIL: load={mult}x tier {tier} attainment "
                      f"inversion: {t_ctl.get(tier, 0.0):.2f} < "
                      f"{t_all[tier]:.2f}", file=sys.stderr)
                ok = False
        # far past the knee the door must actually refuse work; at the
        # intermediate level degrading alone may already clear the backlog
        if mult == max(past_knee) and ctl.shed == 0:
            print(f"FAIL: load={mult}x past the knee shed nothing — the "
                  f"door is not making admit/shed decisions",
                  file=sys.stderr)
            ok = False
        # shed counts as a miss in the reported number
        met = sum(r.met_slo for r in ctl.requests)
        if abs(ctl.slo_attainment - met / len(ctl.requests)) > 1e-12 \
                or any(r.met_slo for r in ctl.requests if r.shed):
            print(f"FAIL: load={mult}x shed requests not counted as "
                  f"misses in attainment", file=sys.stderr)
            ok = False
        # token bit-identity on the admitted set (vs admit-everything)
        toks_all = {r.req_id: tuple(r.tokens_out or ())
                    for r in all_.requests}
        for r in ctl.requests:
            if r.tokens_out is not None and toks_all.get(r.req_id):
                if tuple(r.tokens_out) != toks_all[r.req_id]:
                    print(f"FAIL: load={mult}x req {r.req_id} tokens "
                          f"diverged under admission control",
                          file=sys.stderr)
                    ok = False
                    break
    # the daemon on a follower clock reproduces the replay bit-identically
    top = max(sweep)
    ctl_top = sweep[top][1]["admission"]
    if {r.req_id: tuple(r.tokens_out or ()) for r in rep_daemon.requests} \
            != {r.req_id: tuple(r.tokens_out or ())
                for r in ctl_top.requests}:
        print("FAIL: daemon (VirtualClock door) tokens diverged from "
              "replay", file=sys.stderr)
        ok = False
    if {r.req_id for r in rep_daemon.requests if r.shed} \
            != {r.req_id for r in ctl_top.requests if r.shed}:
        print("FAIL: daemon shed set diverged from replay",
              file=sys.stderr)
        ok = False

    top_reps = sweep[top][1]
    write_summary("e2e_slo", {
        "ok": ok,
        "tokens_identical_across_modes":
            mode_tokens["time"] == mode_tokens["vliw"],
        "load_levels": list(sweep),
        "knee": KNEE,
        **{f"slo_attainment_{m:g}x_{p}": reps[p].slo_attainment
           for m, (_, reps) in sweep.items() for p in reps},
        **{f"goodput_rps_{m:g}x_{p}": reps[p].goodput_rps
           for m, (_, reps) in sweep.items() for p in reps},
        "shed_past_knee": {f"{m:g}x": sweep[m][1]["admission"].shed
                           for m in past_knee},
        "degraded_past_knee": {
            f"{m:g}x": sum(1 for r in sweep[m][1]["admission"].requests
                           if r.degraded_from is not None)
            for m in past_knee},
        "tier_attainment_top_admission":
            {str(t): a for t, a in
             top_reps["admission"].tier_attainment().items()},
        "tier_attainment_top_admit_all":
            {str(t): a for t, a in
             top_reps["admit_all"].tier_attainment().items()},
        "daemon_matches_replay":
            {r.req_id for r in rep_daemon.requests if r.shed}
            == {r.req_id for r in ctl_top.requests if r.shed},
    })
    return ok


def run() -> None:
    """Entry point for the benchmarks/run.py harness."""
    models = _models()
    mode_tokens = bench_modes(models)
    sweep, rep_daemon = bench_front_door(models, n_requests=36)
    assert check(mode_tokens, sweep, rep_daemon), \
        "e2e SLO front-door acceptance failed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    args = ap.parse_args()
    n = 24 if args.quick else 48
    header()
    models = _models()
    mode_tokens = bench_modes(models)
    sweep, rep_daemon = bench_front_door(models, n_requests=n)
    return 0 if check(mode_tokens, sweep, rep_daemon) else 1


if __name__ == "__main__":
    sys.exit(main())
