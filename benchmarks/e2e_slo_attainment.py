"""End-to-end §5.2: multi-tenant serving with SLOs under the three engine
modes (time-multiplexed, per-tenant batched, VLIW JIT). Real token
generation through reduced models; time attributed by the TPU-v5e device
model. Greedy tokens must agree across modes (asserted)."""
from __future__ import annotations

import copy

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import smoke_config
from repro.models import Model
from repro.serving import ServingEngine, Tenant, make_trace


def run() -> None:
    rng = jax.random.PRNGKey(0)

    def mk(arch, seed):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        return m, m.init(jax.random.PRNGKey(seed))

    m1, p1 = mk("gemma3-1b", 1)
    m2, p2 = mk("yi-9b", 2)
    trace = make_trace(["t1", "t2"], rate_hz=1e5, n_per_tenant=3,
                       prompt_len=8, max_new_tokens=4, slo_s=0.002)
    tokens = {}
    for mode in ("time", "batched", "vliw"):
        tenants = [Tenant("t1", m1, p1, cache_len=32, max_batch=4),
                   Tenant("t2", m2, p2, cache_len=32, max_batch=4)]
        eng = ServingEngine(tenants, mode=mode)
        rep = eng.run(copy.deepcopy(trace))
        tokens[mode] = [r.tokens_out for r in
                        sorted(rep.requests, key=lambda r: r.req_id)]
        extra = ""
        if rep.jit:
            extra = (f";mean_group={rep.jit.mean_group:.2f}"
                     f";superkernels={rep.jit.superkernels}"
                     f";modeled_speedup={rep.jit.modeled_speedup:.2f}x"
                     f";waits={rep.jit.waits}"
                     f";evictions={rep.jit.evictions}"
                     f";mid_flight={rep.jit.mid_flight_admissions}")
        emit(f"e2e/{mode}", rep.modeled_time_s * 1e6,
             f"mean_lat_us={rep.mean_latency*1e6:.0f}"
             f";p99_us={rep.p_latency(0.99)*1e6:.0f}"
             f";slo={rep.slo_attainment:.2f}"
             f";tok_s={rep.tokens_per_s:.0f}{extra}")
    assert tokens["time"] == tokens["batched"] == tokens["vliw"], \
        "greedy tokens diverged across engine modes"
    emit("e2e/token_consistency", 0.0, "all_modes_identical=True")
