"""Paper §5.3: coalescing matrix-vector multiplications common in RNN/LSTM
inference yields 2.48× throughput over time-slicing. Shared-weight GEMV
coalescing speedup as a function of the number of coalesced streams, plus a
real interpret-mode execution of the packed GEMV kernel — eager reference
vs the jitted cached matvec regime (core/dispatch.py), the RNN serving
loop's steady-state dispatch path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax
from repro.core import (Coalescer, CostModel, GemmShape, SuperkernelExecutor,
                        V100, make_op)
from repro.kernels.ops import coalesced_matvec

LSTM_GEMV = GemmShape(m=1, n=4096, k=2048, dtype_bytes=4)


def run() -> None:
    cm = CostModel(V100)
    coal = Coalescer(cm)
    for G in (2, 3, 4, 8):
        ops = [make_op(i, "gemv", LSTM_GEMV, tag="lstm_x", model_id="lstm",
                       seq_index=0) for i in range(G)]
        plan = coal.plan(ops)
        t_serial = cm.time_multiplexed([LSTM_GEMV] * G, plan.block)
        emit(f"rnn_gemv/G{G}", plan.est_time_s * 1e6,
             f"speedup={t_serial/plan.est_time_s:.2f}x(paper2.48x);"
             f"shared={plan.shared_operand}")

    # real kernel execution (reduced size): eager reference vs the jitted
    # cached matvec regime — the dispatch path a steady-state RNN serving
    # loop would take tick after tick
    rng = jax.random.PRNGKey(0)
    w = jax.random.normal(rng, (512, 1024), jnp.float32)
    xs = [jax.random.normal(jax.random.fold_in(rng, i), (512,))
          for i in range(4)]
    us = time_jax(lambda: coalesced_matvec(xs, [w] * 4))
    outs = coalesced_matvec(xs, [w] * 4)
    err = max(float(jnp.max(jnp.abs(o - x @ w))) for x, o in zip(xs, outs))
    emit("rnn_gemv/real_G4", us, f"max_err={err:.1e}")
    ex = SuperkernelExecutor(bm=8)
    us_fast = time_jax(lambda: ex.matvec(xs, [w] * 4))
    fast = ex.matvec(xs, [w] * 4)
    err = max(float(jnp.max(jnp.abs(f - o))) for f, o in zip(fast, outs))
    emit("rnn_gemv/real_G4_cached", us_fast,
         f"vs_eager_err={err:.1e};speedup={us / us_fast:.2f}x"
         f";weight_hit_rate={ex.stats.weight_hit_rate:.3f}")
