"""Compiled-lane + live collaborative autotuning acceptance (PR 9 gate).

Two parts, matching the two regimes documented in ``repro.kernels.ops``:

Part A — serving acceptance, runs on ANY host (interpret mode is fine
because every gate here is a correctness/caching property, not wall-clock):

  * >= 4-tenant co-tenancy: live tuning (collaborative AND greedy
    objectives) changes not a single greedy token vs the untuned engine;
  * one exhaustive search per distinct group signature — tune-cache
    misses == |signatures| on the first run;
  * steady state is FREE: re-running the identical trace on the tuned
    engine pays zero tune-cache misses (hit rate 1.0 >= (steps-1)/steps
    for any steps) and zero jitted-dispatch retraces;
  * the Table 1 modeled claim at realistic dims (k, n >= 2048): the
    collaboratively tuned tile strictly beats the greedy tile on the
    coalesced group, while the greedy tile strictly wins the isolated
    envelope GEMM — and for every signature the live tuner actually tuned,
    collaborative is never worse on its own group.

Part B — compiled-lane wall-clock (``REPRO_PALLAS_INTERPRET=0``): the
collaboratively tuned tile must beat the greedy tile in wall-clock on a
G=6 coalesced superkernel at k = n = 2048, compiled (interpret=False), and
both tiles must agree numerically. Interpret-mode wall-clock comparisons
are meaningless (~2 ms/grid-step floor), so on hosts whose jaxlib has no
compiled Pallas lane (CPU: "Only interpret mode is supported") this part
SKIPS — recorded in the JSON summary, exit 0 — rather than gating on
noise. CI runs this bench with REPRO_PALLAS_INTERPRET=0 so the gate arms
itself automatically wherever a real backend exists.

Run:  PYTHONPATH=src python benchmarks/compiled_autotune_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

try:                                     # via the run.py harness
    from benchmarks.common import (emit, header, tuning_summary,
                                   write_summary)
except ImportError:                      # standalone: python benchmarks/...
    from common import emit, header, tuning_summary, write_summary

import repro.kernels.ops as kops
from repro.configs import smoke_config
from repro.core import Autotuner, CostModel, GemmShape, V100
from repro.kernels.ops import execute_superkernel
from repro.models import Model
from repro.serving import ServingEngine, Tenant, two_wave_trace

CM = CostModel(V100)
# realistic-dims witness group for the Table 1 modeled claim: at small k
# the two objectives collapse to the same tile, so the claim is only
# meaningful at k, n >= 2048 (see kernels/ops.py's lane policy)
WITNESS = [GemmShape(16, 2048, 2048, dtype_bytes=4)] * 8


def _tokens(rep):
    return [r.tokens_out for r in sorted(rep.requests,
                                         key=lambda r: r.req_id)]


def _shapes(signature):
    return [GemmShape(m, n, k, dtype_bytes=d, layers=l)
            for m, n, k, d, l in signature]


# ---------------------------------------------------------------------------
# Part A: serving acceptance (any host)
# ---------------------------------------------------------------------------

def bench_serving(n_tenants: int, steps: int):
    cfg = smoke_config("gemma3-1b")
    mdl = Model(cfg, param_dtype=jnp.float32)
    params = mdl.init(jax.random.PRNGKey(0))
    names = [f"t{i}" for i in range(n_tenants)]

    def mk_engine(**kw):
        return ServingEngine([Tenant(n, mdl, params, cache_len=64,
                                     max_batch=2) for n in names],
                             mode="vliw", **kw)

    trace = two_wave_trace(names, [], 1e-5, prompt_len=8,
                           max_new_tokens=steps, slo_s=10.0)
    reps, engines, first_tune = {}, {}, {}
    for label, kw in (("untuned", {}),
                      ("collab", dict(live_tune=True)),
                      ("greedy", dict(live_tune=True,
                                      tune_objective="greedy"))):
        engines[label] = mk_engine(**kw)
        t0 = time.perf_counter()
        reps[label] = engines[label].run(trace)
        wall = time.perf_counter() - t0
        # snapshot: ServeReport.jit aliases the engine's LIVE cumulative
        # stats, which the steady-state rerun below keeps mutating
        tc = first_tune[label] = engines[label].jit.tune_cache.stats.copy()
        emit(f"compiled_autotune/serving/{label}/tenants={n_tenants}",
             wall * 1e6,
             f"steps={steps};tune_hits={tc.hits};tune_misses={tc.misses}"
             f";tune_hit_rate={tc.hit_rate:.3f}"
             f";retraces={reps[label].jit.dispatch.retraces}")
    # steady state: the SAME trace again on the tuned engine — every
    # signature is known, so tuning must cost nothing. ServeReport.jit is
    # engine-lifetime cumulative, so diff the caches around the rerun.
    jit = engines["collab"].jit
    tune_base = jit.tune_cache.stats.copy()
    dispatch_base = jit.executor.stats.copy()
    rep2 = engines["collab"].run(trace)
    rerun = {"tune": jit.tune_cache.stats - tune_base,
             "retraces": jit.executor.stats.retraces
                         - dispatch_base.retraces}
    tc2 = rerun["tune"]
    emit(f"compiled_autotune/serving/collab_rerun/tenants={n_tenants}",
         rep2.wall_time_s * 1e6,
         f"tune_hits={tc2.hits};tune_misses={tc2.misses}"
         f";retraces={rerun['retraces']}")
    return reps, engines, rerun, first_tune["collab"]


def check_serving(reps, engines, rerun, tc1, steps: int):
    ok = True
    if not (_tokens(reps["collab"]) == _tokens(reps["untuned"])
            == _tokens(reps["greedy"])):
        print("FAIL: live tuning changed greedy tokens vs the untuned "
              "engine", file=sys.stderr)
        ok = False
    jit = engines["collab"].jit
    n_sigs = len(jit.tuner.results)
    if not 0 < tc1.misses == n_sigs:
        print(f"FAIL: {tc1.misses} tune searches for {n_sigs} distinct "
              "group signatures (must be exactly one each)",
              file=sys.stderr)
        ok = False
    tc2 = rerun["tune"]
    hits_needed = (steps - 1) / steps
    if tc2.misses != 0 or tc2.hit_rate < hits_needed:
        print(f"FAIL: steady-state rerun paid {tc2.misses} tune "
              f"search(es), hit rate {tc2.hit_rate:.3f} < "
              f"{hits_needed:.3f}", file=sys.stderr)
        ok = False
    if rerun["retraces"] != 0:
        print(f"FAIL: {rerun['retraces']} jitted-dispatch "
              "retrace(s) on the steady-state rerun — tuned blocks are "
              "churning compile keys", file=sys.stderr)
        ok = False
    # modeled Table 1 direction on every signature the tuner actually saw,
    # evaluated under the engine's OWN cost model — the live tuner's argmin
    # is only guaranteed to win under the device model it minimized
    ecm = jit.cost
    eat = Autotuner(ecm)
    for res in jit.tuner.results.values():
        shapes = _shapes(res.signature)
        g = eat.tune_group(shapes, "greedy",
                           shared_operand=res.shared_operand)
        t_c = ecm.coalesced_time(shapes, res.block,
                                 shared_operand=res.shared_operand)
        t_g = ecm.coalesced_time(shapes, g,
                                 shared_operand=res.shared_operand)
        if t_c > t_g * (1 + 1e-9):
            print(f"FAIL: collaborative tile loses its own group "
                  f"{res.signature}: {t_c:.3e}s vs greedy {t_g:.3e}s",
                  file=sys.stderr)
            ok = False
    # strict separation at realistic dims (paper's V100 Table 1 setting)
    at = Autotuner(CM)
    collab = at.tune_group(WITNESS, "collaborative")
    greedy = at.tune_group(WITNESS, "greedy")
    t_c = CM.coalesced_time(WITNESS, collab)
    t_g = CM.coalesced_time(WITNESS, greedy)
    iso_c = CM.gemm_time(WITNESS[0], collab)
    iso_g = CM.gemm_time(WITNESS[0], greedy)
    emit("compiled_autotune/modeled_witness", t_c * 1e6,
         f"greedy_us={t_g * 1e6:.1f};speedup={t_g / t_c:.3f}x"
         f";iso_regression={iso_c / iso_g - 1.0:.2f}")
    if not (collab != greedy and t_c < t_g and iso_g < iso_c):
        print("FAIL: Table 1 direction lost at realistic dims: "
              f"collab={collab} greedy={greedy} group {t_c:.3e}/{t_g:.3e} "
              f"iso {iso_c:.3e}/{iso_g:.3e}", file=sys.stderr)
        ok = False
    return ok, {
        "tokens_identical": _tokens(reps["collab"]) ==
            _tokens(reps["untuned"]) == _tokens(reps["greedy"]),
        "first_run": {"hits": tc1.hits, "misses": tc1.misses,
                      "hit_rate": round(tc1.hit_rate, 4),
                      "signatures": n_sigs},
        "steady_rerun": {"hits": tc2.hits, "misses": tc2.misses,
                         "hit_rate": round(tc2.hit_rate, 4),
                         "retraces": rerun["retraces"]},
        "modeled_witness_speedup": t_g / t_c,
        "modeled_witness_iso_regression": iso_c / iso_g - 1.0,
    }


# ---------------------------------------------------------------------------
# Part B: compiled-lane wall-clock (skips on interpret-only hosts)
# ---------------------------------------------------------------------------

def bench_compiled(iters: int):
    """Wall-clock collaborative vs greedy tiles on a compiled G=6
    superkernel at k = n = 2048 (>= 4-tenant co-tenancy, realistic dims)."""
    at = Autotuner(CM)
    group = [GemmShape(16, 2048, 2048, dtype_bytes=4)] * 6
    collab = at.tune_group(group, "collaborative")
    greedy = at.tune_group(group, "greedy")
    probs = []
    for i, s in enumerate(group):
        ka, kw = jax.random.split(jax.random.PRNGKey(i), 2)
        probs.append((jax.random.normal(ka, (s.m, s.k), jnp.float32),
                      jax.random.normal(kw, (s.k, s.n), jnp.float32)))

    def run(block):
        return execute_superkernel(probs, bm=block.bm, bn=block.bn,
                                   bk=block.bk, interpret=False)

    walls, outs = {}, {}
    for label, block in (("collab", collab), ("greedy", greedy)):
        outs[label] = jax.block_until_ready(run(block))   # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = run(block)
        jax.block_until_ready(out)
        walls[label] = (time.perf_counter() - t0) / iters * 1e6
        emit(f"compiled_autotune/compiled/{label}", walls[label],
             f"bm={block.bm};bn={block.bn};bk={block.bk};iters={iters}")
    ok = True
    for oc, og in zip(outs["collab"], outs["greedy"]):
        import numpy as np
        if not np.allclose(np.asarray(oc), np.asarray(og), rtol=1e-5,
                           atol=1e-5):
            print("FAIL: collaborative and greedy tiles disagree "
                  "numerically on the compiled lane", file=sys.stderr)
            ok = False
    if walls["collab"] >= walls["greedy"]:
        print(f"FAIL: collaborative tile not faster wall-clock under "
              f"co-tenancy: {walls['collab']:.1f}us vs greedy "
              f"{walls['greedy']:.1f}us", file=sys.stderr)
        ok = False
    return ok, {"collab_us": walls["collab"], "greedy_us": walls["greedy"],
                "speedup": walls["greedy"] / walls["collab"],
                "collab_block": [collab.bm, collab.bn, collab.bk],
                "greedy_block": [greedy.bm, greedy.bn, greedy.bk]}


# ---------------------------------------------------------------------------

def run_all(n_tenants: int, steps: int, iters: int) -> bool:
    # honor REPRO_PALLAS_INTERPRET=0 only where a compiled lane exists;
    # otherwise fall back to interpret so Part A still gates correctness
    lane = kops.compiled_lane_available()
    if not kops.interpret_default() and not lane:
        kops.set_interpret(True)
        print("# no compiled Pallas lane on this host: serving part runs "
              "interpret-mode; wall-clock part SKIPPED", file=sys.stderr)
    reps, engines, rerun, tc1 = bench_serving(n_tenants, steps)
    ok, serving_summary = check_serving(reps, engines, rerun, tc1, steps)
    if lane:
        ok_b, compiled_summary = bench_compiled(iters)
        ok = ok and ok_b
    else:
        compiled_summary = "skipped (interpret-only host)"
        emit("compiled_autotune/compiled/skipped", 0.0,
             "no_compiled_pallas_lane")
    write_summary("compiled_autotune", {
        "ok": ok, "tenants": n_tenants, "steps": steps,
        "compiled_lane": lane,
        "serving": serving_summary,
        "compiled": compiled_summary,
        "tuning": tuning_summary(engines["collab"].jit),
    })
    return ok


def run() -> None:
    """Entry point for the benchmarks/run.py harness."""
    assert run_all(n_tenants=6, steps=6, iters=5), \
        "compiled autotune acceptance failed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for the CI smoke run")
    args = ap.parse_args()
    n_tenants = 4 if args.quick else 6
    steps = 4 if args.quick else 8
    header()
    return 0 if run_all(n_tenants, steps, iters=3 if args.quick else 10) \
        else 1


if __name__ == "__main__":
    sys.exit(main())
