"""Shared benchmark helpers. Every benchmark prints ``name,us_per_call,
derived`` CSV rows (one per measured configuration), and may additionally
persist a machine-readable JSON summary (``write_summary``) — CI uploads
the summary directory as a workflow artifact so the perf trajectory is
inspectable per commit."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def write_summary(name: str, data: Dict[str, Any]) -> str:
    """Persist one benchmark's JSON summary.

    Written to ``$BENCH_SUMMARY_DIR`` (default ``bench-summaries/`` under
    the current directory); CI uploads that directory as a workflow
    artifact. Values must be JSON-serializable — keep them to the scalar
    acceptance numbers (speedups, hit rates, coalesced-group counts), not
    raw traces."""
    out_dir = os.environ.get("BENCH_SUMMARY_DIR", "bench-summaries")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def tuning_summary(jit) -> Dict[str, Any]:
    """Block-tuning facts for a finished ``VLIWJit``, for JSON summaries.

    Reports the tune-cache counters (hit rate is a gated acceptance
    criterion in compiled_autotune_bench.py), every LIVE-tuned
    (bm, bn, bk) per (device, objective, group signature), and the block
    each memoized superkernel plan actually dispatched with — so summaries
    carry per-group tile choices even on benches that run with live
    tuning off. Reads go through ``PlanCache.peek`` (stats-neutral)."""
    st = jit.tune_cache.stats
    tuned: Dict[str, List[int]] = {}
    for key in jit.tune_cache.keys():
        res = jit.tune_cache.peek(key)
        if res is None:
            continue
        _, dev, objective, sig, shared = key
        dims = ",".join(f"{m}x{n}x{k}" for m, n, k, *_ in sig[:4])
        label = (f"dev{dev}/{objective}/g{len(sig)}[{dims}"
                 f"{',...' if len(sig) > 4 else ''}]"
                 f"{'/shared' if shared else ''}")
        tuned[label] = [res.block.bm, res.block.bn, res.block.bk]
    plan_blocks: Dict[str, List[int]] = {}
    for key in jit.block_plans.keys():
        val = jit.block_plans.peek(key)
        if val is None:
            continue
        b = val[0]                   # memo value is (block, waste, time)
        plan_blocks.setdefault(f"g{len(key[2])}", []).append(
            [b.bm, b.bn, b.bk])
    return {
        "live_tune": jit.live_tune,
        "tune_cache": {"hits": st.hits, "misses": st.misses,
                       "hit_rate": round(st.hit_rate, 4),
                       "invalidations": st.invalidations,
                       "evictions": st.evictions,
                       "entries": len(jit.tune_cache)},
        "tuned_blocks": tuned,
        "plan_blocks": {k: sorted(set(map(tuple, v)))
                        for k, v in plan_blocks.items()},
    }


def time_jax(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Wall-clock microseconds per call of a jitted function (CPU)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def header() -> None:
    print("name,us_per_call,derived")
