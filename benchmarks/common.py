"""Shared benchmark helpers. Every benchmark prints ``name,us_per_call,
derived`` CSV rows (one per measured configuration)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_jax(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Wall-clock microseconds per call of a jitted function (CPU)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def header() -> None:
    print("name,us_per_call,derived")
