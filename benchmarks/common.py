"""Shared benchmark helpers. Every benchmark prints ``name,us_per_call,
derived`` CSV rows (one per measured configuration), and may additionally
persist a machine-readable JSON summary (``write_summary``) — CI uploads
the summary directory as a workflow artifact so the perf trajectory is
inspectable per commit."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def write_summary(name: str, data: Dict[str, Any]) -> str:
    """Persist one benchmark's JSON summary.

    Written to ``$BENCH_SUMMARY_DIR`` (default ``bench-summaries/`` under
    the current directory); CI uploads that directory as a workflow
    artifact. Values must be JSON-serializable — keep them to the scalar
    acceptance numbers (speedups, hit rates, coalesced-group counts), not
    raw traces."""
    out_dir = os.environ.get("BENCH_SUMMARY_DIR", "bench-summaries")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def time_jax(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Wall-clock microseconds per call of a jitted function (CPU)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def header() -> None:
    print("name,us_per_call,derived")
