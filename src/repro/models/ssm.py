"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Two execution paths, matching the paper's duality:

* ``ssd_chunked`` — training / prefill: the quadratic *intra-chunk* part is
  computed attention-like with matmuls (MXU-friendly), the *inter-chunk*
  part is a linear recurrence over chunk states via ``jax.lax.scan``.
* ``ssd_decode_step`` — single-token recurrent update h = a·h + dt·B⊗x,
  y = C·h + D·x (O(1) per token; this is what makes long_500k decodable).

Shapes: d_inner = expand·d_model, H heads of size P = head_dim,
state size N = d_state, single B/C group (n_groups = 1).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import Params, dense_init, rmsnorm


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype) -> Params:
    d_inner = cfg.expand * d_model
    H = cfg.num_heads(d_model)
    N = cfg.d_state
    conv_dim = d_inner + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt_init = jnp.exp(
        jax.random.uniform(k3, (H,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": dense_init(k1, (d_model, 2 * d_inner + 2 * N + H), dtype),
        "conv_w": (jax.random.normal(k2, (cfg.d_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(k4, (d_inner, d_model), dtype),
    }


def _split_zxbcdt(zxbcdt: jax.Array, d_inner: int, N: int):
    """THE in_proj packing layout: [z (d_inner) | xBC (d_inner + 2N) |
    dt (H)]. Single source of truth — both the full-sequence path
    (``_split_in_proj``) and the decode path (``decode_core``, which the
    JIT's SSM template feeds from a declared GEMM) split through here."""
    return jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)


def _split_in_proj(params: Params, u: jax.Array, cfg: SSMConfig, d_model: int):
    d_inner = cfg.expand * d_model
    H = cfg.num_heads(d_model)
    N = cfg.d_state
    z, xBC, dt = _split_zxbcdt(u @ params["in_proj"], d_inner, N)
    return z, xBC, dt, d_inner, H, N


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xBC: [B, S, Cdim]; w: [K, Cdim]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    S = xBC.shape[1]
    for i in range(K):  # K is tiny (4); unrolled taps keep HLO simple
        out = out + pad[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32))


def ssd_chunked(params: Params, u: jax.Array, cfg: SSMConfig,
                return_state: bool = False):
    """Full-sequence SSD. u: [B, S, d_model] -> [B, S, d_model].

    With ``return_state=True`` also returns the recurrent cache
    {"conv", "h"} after the last position (used by serving prefill).
    """
    Bsz, S0, d_model = u.shape
    Q = cfg.chunk_size
    # right-pad the sequence to a chunk multiple; padded steps have dt ->
    # softplus(large negative) ~ 0 so they do not perturb the final state.
    S = ((S0 + Q - 1) // Q) * Q
    if S != S0:
        u = jnp.pad(u, ((0, 0), (0, S - S0), (0, 0)))
    nc = S // Q
    z, xBC, dt, d_inner, H, N = _split_in_proj(params, u, cfg, d_model)
    if S != S0:
        dt = dt.at[:, S0:, :].set(-30.0)  # freeze state on padded steps
    P = cfg.head_dim

    conv_tail = xBC[:, S0 - (cfg.d_conv - 1):S0, :]  # pre-conv inputs for decode
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"]).astype(u.dtype)
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(Bsz, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = -jnp.exp(params["A_log"])                                    # [H] < 0

    # Precision policy (TPU-native; §Perf iteration M1): the scalar decay
    # chain (alpha/cum/decay/state scan) stays fp32 for stability, but the
    # four big einsums and the stacked per-chunk states run in the model
    # compute dtype (bf16 in production) with fp32 MXU accumulation —
    # profiling showed fp32 SSD intermediates dominated the memory roofline
    # term (chunk states alone: 1.2 TB/step/chip at prefill_32k).
    cdt = u.dtype

    # chunked views
    xc = x.reshape(Bsz, nc, Q, H, P).astype(cdt)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(cdt)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(cdt)
    dtc = dt.reshape(Bsz, nc, Q, H)

    alpha = a[None, None, None, :] * dtc                   # [B,nc,Q,H] (<=0)
    cum = jnp.cumsum(alpha, axis=2)                        # [B,nc,Q,H]
    total = cum[:, :, -1]                                  # [B,nc,H]

    # ---- intra-chunk (quadratic, matmul form) --------------------------------
    # L[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Q(i),Q(j),H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                    preferred_element_type=jnp.float32)    # [B,nc,Q,Q]
    scores = (CB[..., None] * L).astype(cdt)               # [B,nc,Q,Q,H]
    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(cdt)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt,
                         preferred_element_type=jnp.float32)

    # ---- chunk states + inter-chunk recurrence -------------------------------
    # §Perf iteration M2: the inter-chunk contribution is computed INSIDE the
    # recurrence scan, so the [nc, B, H, P, N] chunk-state stack is never
    # materialized (it was the single largest HBM consumer: 1.2 TB/step at
    # prefill_32k), and ``states`` is emitted directly in scan-major layout
    # (saves a full-buffer transpose pass).
    decay_end = jnp.exp(total[:, :, None, :] - cum).astype(cdt)
    states = jnp.einsum("bcjh,bcjn,bcjhp->cbhpn", decay_end, Bc, xdt,
                        preferred_element_type=jnp.float32).astype(cdt)
    expcum = jnp.exp(cum).astype(cdt)                      # [B,nc,Q,H]

    def step(h, inputs):
        st, tot, c_c, ec_c = inputs  # [B,H,P,N], [B,H], [B,Q,N], [B,Q,H]
        # ys stay fp32: mixed dtypes at the scan's stacking
        # dynamic-update-slice make XLA round-trip the WHOLE [nc,...] buffer
        # through convert every iteration (measured 44 TB of phantom
        # traffic); uniform-dtype ys are written slice-by-slice in place.
        y_c = jnp.einsum("bin,bhpn,bih->bihp", c_c, h.astype(cdt), ec_c,
                         preferred_element_type=jnp.float32)
        h = jnp.exp(tot)[:, :, None, None] * h + st.astype(jnp.float32)
        return h, y_c

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, y_inter = jax.lax.scan(
        step, h0,
        (states, total.transpose(1, 0, 2),
         Cc.transpose(1, 0, 2, 3), expcum.transpose(1, 0, 2, 3)))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)             # [B,nc,Q,H,P]

    y = (y_intra + y_inter.astype(jnp.float32)).reshape(Bsz, S, H, P)
    y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner)

    # gate + norm in one fp32 pass, then back to the compute dtype
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    y = rmsnorm(y, params["norm"])
    out = y @ params["out_proj"]
    if S != S0:
        out = out[:, :S0]
    if return_state:
        return out, {"conv": conv_tail.astype(u.dtype), "h": h_final}
    return out


def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig, dtype
                   ) -> Dict[str, jax.Array]:
    d_inner = cfg.expand * d_model
    H = cfg.num_heads(d_model)
    N = cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner + 2 * N), dtype),
        "h": jnp.zeros((batch, H, cfg.head_dim, N), jnp.float32),
    }


def decode_core(params: Params, zxbcdt: jax.Array,
                cache: Dict[str, jax.Array], cfg: SSMConfig, d_model: int
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Everything between the two decode-step projections.

    Takes the in-projection output ``zxbcdt`` [B, 2·d_inner + 2N + H] and
    the per-layer recurrent cache; returns the gated/normed ``y``
    [B, d_inner] *ready for the out projection* plus the updated cache.
    This is the per-stage seam the JIT's SSM decode template
    (core/jit.py ``build_ssm_decode_template``) builds on: the in/out
    projections become declared ``GemmStage``s (coalescible across
    tenants) while this selective-scan recurrence runs as glue — keeping
    exactly ONE copy of the recurrence math shared with
    ``ssd_decode_step``."""
    Bsz = zxbcdt.shape[0]
    d_inner = cfg.expand * d_model
    H = cfg.num_heads(d_model)
    N = cfg.d_state
    z, xBC, dt = _split_zxbcdt(zxbcdt, d_inner, N)
    P = cfg.head_dim

    # causal conv over the cached window + the new input
    window = jnp.concatenate([cache["conv"],
                              xBC[:, None].astype(cache["conv"].dtype)], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    xBC_t = jax.nn.silu(conv + params["conv_b"].astype(jnp.float32))
    new_conv = window[:, 1:]

    x, Bm, Cm = jnp.split(xBC_t, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(Bsz, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = -jnp.exp(params["A_log"])

    decay = jnp.exp(a[None] * dt)                          # [B,H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), x)
    h = decay[:, :, None, None] * cache["h"] + dBx         # [B,H,P,N]
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + params["D"][None, :, None] * x
    y = y.reshape(Bsz, d_inner)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(zxbcdt.dtype), params["norm"])
    return y, {"conv": new_conv, "h": h}


def ssd_decode_step(params: Params, u: jax.Array, cache: Dict[str, jax.Array],
                    cfg: SSMConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token recurrent update. u: [B, 1, d_model]."""
    Bsz, _, d_model = u.shape
    y, new_cache = decode_core(params, u[:, 0] @ params["in_proj"],
                               cache, cfg, d_model)
    out = (y @ params["out_proj"])[:, None]
    return out, new_cache


def ssd_reference(params: Params, u: jax.Array, cfg: SSMConfig) -> jax.Array:
    """Naive step-by-step recurrence oracle (for tests)."""
    Bsz, S, d_model = u.shape
    cache = init_ssm_cache(Bsz, d_model, cfg, u.dtype)
    outs = []
    for t in range(S):
        y, cache = ssd_decode_step(params, u[:, t:t + 1], cache, cfg)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)
