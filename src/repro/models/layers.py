"""Shared neural-net building blocks (pure JAX, functional).

Conventions used throughout the model zoo:
  * params are plain dict pytrees of jnp arrays;
  * per-layer params are STACKED on a leading layer axis and consumed with
    ``jax.lax.scan`` so HLO size / compile time is O(1) in depth;
  * matmuls run in the param dtype (bf16 by default), reductions
    (norms, softmax, losses) in fp32.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (stddev = scale / sqrt(fan_in))."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * scale) * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def init_rmsnorm(d: int, dtype) -> jax.Array:
    # stored as the deviation from 1.0 (gemma convention); init to zeros.
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    # computed on HOST (numpy) so the table is one literal constant: a
    # device-side ``theta ** x`` evaluates through the runtime pow kernel
    # eagerly but through XLA's constant folder under jit, and the two
    # disagree in the last ulp — which would break bit-identity between
    # eager per-layer glue and jitted scan-over-layers bodies
    half = head_dim // 2
    return jnp.asarray(
        (1.0 / (theta ** (np.arange(half, dtype=np.float64) / half))
         ).astype(np.float32))


# Host-precomputed rope cos/sin tables, one per (head_dim, theta). The trig
# itself must NOT be evaluated on device: XLA's standalone cos/sin kernels
# and its fused-loop vectorized versions disagree in the last ulp, so the
# same ``cos(pos * freq)`` computes different bits inside a jitted
# scan-over-layers body than in eager per-layer glue. A host table + device
# gather is bit-exact in every execution regime. 8192 positions bounds every
# cache/prefill geometry this repo serves (gather clips beyond it).
_ROPE_TABLE_POSITIONS = 8192
_ROPE_TRIG: Dict[Any, Any] = {}


def _rope_trig_tables(head_dim: int, theta: float):
    # cache NUMPY arrays only — materializing device arrays here would leak
    # tracers when the first call happens inside a jit/scan trace; the
    # use-site jnp.asarray embeds them as constants under trace and
    # transfers on the eager path
    key = (head_dim, float(theta))
    tab = _ROPE_TRIG.get(key)
    if tab is None:
        half = head_dim // 2
        freqs = 1.0 / (theta ** (np.arange(half, dtype=np.float64) / half))
        ang = np.arange(_ROPE_TABLE_POSITIONS,
                        dtype=np.float64)[:, None] * freqs
        tab = (np.cos(ang).astype(np.float32),
               np.sin(ang).astype(np.float32))
        _ROPE_TRIG[key] = tab
    return tab


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq].

    cos/sin come from a host-precomputed per-position table, so the trig is
    a bit-exact gather in every execution regime — part of the bit-identity
    contract between the per-layer and scan-over-layers template regimes
    (the remaining fma-contraction hazard in the rotation is handled by the
    JIT running per-layer glue through ``jax.jit``, core/jit.py)."""
    head_dim = x.shape[-1]
    cos_t, sin_t = _rope_trig_tables(head_dim, theta)
    idx = positions.astype(jnp.int32)
    cos = jnp.asarray(cos_t)[idx][..., None, :]  # [..., seq, 1, half]
    sin = jnp.asarray(sin_t)[idx][..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embeddings [seq, d_model]."""
    half = d_model // 2
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(half, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(half - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(params: Params, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(x @ params["w_gate"])
    up = x @ params["w_up"]
    return (gate * up) @ params["w_down"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    """Mean next-token CE in fp32. logits [..., V], labels int [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
