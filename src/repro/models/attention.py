"""Grouped-query attention (GQA/MQA) with optional sliding-window locality.

Supports three execution modes:
  * full  — training / prefill self-attention over the whole sequence
    (causal or bidirectional), optional sliding window;
  * decode — one new token against a pre-filled KV cache, updating the cache
    in place (functionally);
  * cross — encoder-decoder cross attention (whisper), bidirectional over a
    fixed memory.

Layer locality (``is_global``) is a *traced* per-layer boolean so that
heterogeneous local/global stacks (gemma3 5:1, llama4 3:1, hymba) stay
homogeneous under ``jax.lax.scan``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_rope, dense_init

NEG_INF = -2.0e38


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, num_heads * head_dim), dtype),
        "wk": dense_init(kk, (d_model, num_kv_heads * head_dim), dtype),
        "wv": dense_init(kv, (d_model, num_kv_heads * head_dim), dtype),
        "wo": dense_init(ko, (num_heads * head_dim, d_model), dtype),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B,S,Hkv,G,hd]; k: [B,T,Hkv,hd] -> scores [B,Hkv,G,S,T] (fp32)."""
    return jnp.einsum("bshgd,bthd->bhgst", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: [B,Hkv,G,S,T]; v: [B,T,Hkv,hd] -> [B,S,Hkv,G,hd]."""
    return jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))


def _locality_mask(rows: jax.Array, cols: jax.Array, is_global, window: int,
                   causal: bool) -> jax.Array:
    """Boolean mask [S, T]: True = attendable."""
    rows = rows[:, None]
    cols = cols[None, :]
    ok = cols <= rows if causal else jnp.ones((rows.shape[0], cols.shape[1]), bool)
    if window > 0:
        local_ok = ok & (cols > rows - window)
        ok = jnp.where(jnp.asarray(is_global), ok, local_ok)
    return ok


# sequences at or above this length use the chunked (memory-efficient)
# attention path: never materialize [B, H, S, S]
CHUNKED_THRESHOLD = 2048
Q_CHUNK = 512


def _attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       is_global, window: int, causal: bool,
                       head_dim: int) -> jax.Array:
    """Flash-style chunked attention: scan over q chunks, full-row scores per
    chunk only ([B, Hkv, G, bq, S] lives transiently). The chunk body is
    rematerialized in the backward pass, so training memory stays
    O(S·bq) instead of O(S²). q: [B,S,Hkv,G,hd]; k,v: [B,S,Hkv,hd].

    BANDED local layers (§Perf W1): when ``window > 0`` and the window band
    fits well under S, local layers take a lax.cond branch that slices only
    the [bq + window] K/V band per q chunk instead of masking full-S scores
    — a S/(bq+window)× cut in attention compute AND score traffic for the
    5:1 / 3:1 local:global stacks (gemma3, llama4, hymba). ``is_global`` is
    a traced per-layer scalar, so one homogeneous scan body serves both
    layer kinds.
    """
    B, S, Hkv, G, hd = q.shape
    bq = Q_CHUNK
    assert S % bq == 0, (S, bq)
    nq = S // bq
    qc = q.reshape(B, nq, bq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    cols = jnp.arange(S)
    Wlen = bq + window                      # band length per q chunk

    def scores_to_out(s, ok, vv):
        s = s / jnp.sqrt(jnp.float32(hd))
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgst,bthd->bshgd", p, vv.astype(jnp.float32))

    def full_branch(qi, rows, idx):
        s = jnp.einsum("bshgd,bthd->bhgst", qi, k,
                       preferred_element_type=jnp.float32)
        ok = cols[None, :] <= rows[:, None] if causal else \
            jnp.ones((bq, S), bool)
        return scores_to_out(s, ok, v)

    def banded_branch(qi, rows, idx):
        start = jnp.clip(idx * bq - window, 0, S - Wlen)
        kb = jax.lax.dynamic_slice_in_dim(k, start, Wlen, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, Wlen, axis=1)
        bcols = start + jnp.arange(Wlen)
        s = jnp.einsum("bshgd,bthd->bhgst", qi, kb,
                       preferred_element_type=jnp.float32)
        ok = (bcols[None, :] <= rows[:, None]) \
            & (bcols[None, :] > rows[:, None] - window)
        return scores_to_out(s, ok, vb)

    def masked_fallback(qi, rows, idx):
        """Old semantics for window bands too wide to slice: full scores
        with the locality mask selected by the traced flag."""
        s = jnp.einsum("bshgd,bthd->bhgst", qi, k,
                       preferred_element_type=jnp.float32)
        ok = cols[None, :] <= rows[:, None] if causal else \
            jnp.ones((bq, S), bool)
        if window > 0:
            local = ok & (cols[None, :] > rows[:, None] - window)
            ok = jnp.where(jnp.asarray(is_global), ok, local)
        return scores_to_out(s, ok, v)

    def chunk(carry, inp):
        qi, idx = inp                                   # [B,bq,Hkv,G,hd]
        rows = idx * bq + jnp.arange(bq)
        if window > 0 and causal and Wlen < S:
            o = jax.lax.cond(jnp.asarray(is_global), full_branch,
                             banded_branch, qi, rows, idx)
        else:
            o = masked_fallback(qi, rows, idx)
        return carry, o.astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(chunk, prevent_cse=False),
                           None, (qc, jnp.arange(nq)))
    # outs: [nq, B, bq, Hkv, G, hd]
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hkv * G * hd)


def attention_full(params: Params, x: jax.Array, *, num_heads: int,
                   num_kv_heads: int, head_dim: int, rope_theta: float,
                   is_global=True, window: int = 0, causal: bool = True,
                   use_rope: bool = True,
                   positions: Optional[jax.Array] = None) -> jax.Array:
    """Self-attention over the full sequence. x: [B, S, d] -> [B, S, d]."""
    B, S, _ = x.shape
    G = num_heads // num_kv_heads
    q = _split_heads(x @ params["wq"], num_heads, head_dim)
    k = _split_heads(x @ params["wk"], num_kv_heads, head_dim)
    v = _split_heads(x @ params["wv"], num_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q = q.reshape(B, S, num_kv_heads, G, head_dim)
    if S >= CHUNKED_THRESHOLD and S % Q_CHUNK == 0:
        out = _attention_chunked(q, k, v, is_global=is_global, window=window,
                                 causal=causal, head_dim=head_dim)
        return out.astype(x.dtype) @ params["wo"]
    scores = _gqa_scores(q, k) / jnp.sqrt(jnp.float32(head_dim))
    mask = _locality_mask(jnp.arange(S), jnp.arange(S), is_global, window, causal)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(p, v).reshape(B, S, num_heads * head_dim).astype(x.dtype)
    return out @ params["wo"]


def attention_decode(params: Params, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, *, num_heads: int,
                     num_kv_heads: int, head_dim: int, rope_theta: float,
                     is_global=True, window: int = 0,
                     use_rope: bool = True,
                     k_scale=None, v_scale=None):
    """One-token decode against a KV cache.

    x: [B, 1, d]; k_cache/v_cache: [B, Hkv, S, hd]; pos: int32 [B] — the
    per-row index the new token is written at (tokens 0..pos[b] attendable).
    Per-row positions are what makes continuous batching possible: requests
    at different depths share one decode batch (serving/engine.py).

    int8 KV mode (§Perf K1): when ``k_scale/v_scale`` [B,Hkv,S,1] are given,
    the caches are int8; the new token is quantized on write and the (banded)
    read is dequantized into the compute dtype — halving decode's dominant
    roofline term (cache bandwidth). Returns
    (y, kc, vc) or (y, kc, vc, k_scale, v_scale) accordingly.
    """
    from repro.models.kvquant import dequantize, quantize
    quant = k_scale is not None
    B, _, _ = x.shape
    S = k_cache.shape[2]
    G = num_heads // num_kv_heads
    pos = jnp.broadcast_to(jnp.asarray(pos), (B,))
    q = _split_heads(x @ params["wq"], num_heads, head_dim)     # [B,1,H,hd]
    k = _split_heads(x @ params["wk"], num_kv_heads, head_dim)  # [B,1,Hkv,hd]
    v = _split_heads(x @ params["wv"], num_kv_heads, head_dim)
    posb = pos[:, None]
    if use_rope:
        q = apply_rope(q, posb, rope_theta)
        k = apply_rope(k, posb, rope_theta)
    # write each row's new K/V at its own index ``pos[b]``. Mask-select
    # instead of vmap(dynamic_update_slice): the latter lowers to a scatter
    # that XLA round-trips through fp32 (whole-cache convert per layer —
    # §Perf L2); the where-form stays in the cache dtype and fuses with the
    # attention read.
    write = (jnp.arange(S)[None, :] == pos[:, None])      # [B, S]
    wmask = write[:, None, :, None]
    k_t = k.transpose(0, 2, 1, 3)
    v_t = v.transpose(0, 2, 1, 3)
    if quant:
        kq, ks_new = quantize(k_t, scale_dtype=k_scale.dtype)
        vq, vs_new = quantize(v_t, scale_dtype=v_scale.dtype)
        k_cache = jnp.where(wmask, kq, k_cache)
        v_cache = jnp.where(wmask, vq, v_cache)
        k_scale = jnp.where(wmask, ks_new, k_scale)
        v_scale = jnp.where(wmask, vs_new, v_scale)
    else:
        k_cache = jnp.where(wmask, k_t.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(wmask, v_t.astype(v_cache.dtype), v_cache)
    q = q.reshape(B, 1, num_kv_heads, G, head_dim)
    cdt = x.dtype

    # Serving precision policy (§Perf L1): the QK and PV dots run in the
    # CACHE dtype (MXU accumulates fp32 internally); only the softmax is
    # fp32. Requesting f32 dot outputs (or upcasting V) makes XLA
    # materialize an fp32 COPY of the whole KV cache per layer — measured
    # 327 GB/step of phantom cache traffic on llama4 decode_32k.
    def _attend(kc, vc, ks, vs, col_idx, plimit):
        """col_idx: absolute positions of kc's entries [B or 1, T]."""
        if quant:
            kc = dequantize(kc, ks, dtype=cdt)
            vc = dequantize(vc, vs, dtype=cdt)
        scores = jnp.einsum("bshgd,bhtd->bhgst", q.astype(kc.dtype), kc)
        scores = scores.astype(jnp.float32) / jnp.sqrt(
            jnp.float32(head_dim))
        ok = (col_idx <= pos[:, None]) & (col_idx > plimit[:, None])
        scores = jnp.where(ok[:, None, None, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhgst,bhtd->bshgd", p.astype(vc.dtype), vc)

    idx = jnp.arange(S)
    neg = jnp.full((B,), -1)
    dummy = jnp.zeros((B, num_kv_heads, S, 1), cdt)
    ks_in = k_scale if quant else dummy
    vs_in = v_scale if quant else dummy

    def full_attend(kc, vc, ks, vs):
        limit = jnp.where(jnp.asarray(is_global) | (window <= 0),
                          neg, pos - window)
        return _attend(kc, vc, ks, vs, idx[None, :], limit)

    if 0 < window < S:
        # banded decode (§Perf W1): local layers read only the last
        # ``window`` cache entries — an S/window cut in cache traffic for
        # sliding-window layers (gemma3 32× at decode_32k).
        def banded(kc, vc, ks, vs):
            # per-row band (rows decode at different depths under
            # continuous batching)
            start = jnp.clip(pos - window + 1, 0, S - window)   # [B]
            slc = jax.vmap(lambda c, s: jax.lax.dynamic_slice_in_dim(
                c, s, window, axis=1))
            kb, vb = slc(kc, start), slc(vc, start)
            ksb, vsb = slc(ks, start), slc(vs, start)
            bcols = start[:, None] + jnp.arange(window)[None, :]
            return _attend(kb, vb, ksb, vsb, bcols, pos - window)

        out = jax.lax.cond(jnp.asarray(is_global), full_attend, banded,
                           k_cache, v_cache, ks_in, vs_in)
    else:
        out = full_attend(k_cache, v_cache, ks_in, vs_in)
    out = out.reshape(B, 1, num_heads * head_dim).astype(x.dtype)
    y = out @ params["wo"]
    if quant:
        return y, k_cache, v_cache, k_scale, v_scale
    return y, k_cache, v_cache


def attention_cross(params: Params, x: jax.Array, k_mem: jax.Array,
                    v_mem: jax.Array, *, num_heads: int, num_kv_heads: int,
                    head_dim: int) -> jax.Array:
    """Cross attention against precomputed memory K/V [B, Hkv, T, hd]."""
    B, S, _ = x.shape
    G = num_heads // num_kv_heads
    q = _split_heads(x @ params["wq"], num_heads, head_dim)
    q = q.reshape(B, S, num_kv_heads, G, head_dim)
    scores = jnp.einsum("bshgd,bhtd->bhgst", q, k_mem,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(head_dim))
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bshgd", p, v_mem.astype(jnp.float32))
    out = out.reshape(B, S, num_heads * head_dim).astype(x.dtype)
    return out @ params["wo"]


def project_memory_kv(params: Params, mem: jax.Array, *, num_kv_heads: int,
                      head_dim: int) -> Tuple[jax.Array, jax.Array]:
    """Project encoder output into cross-attention K/V [B, Hkv, T, hd]."""
    k = _split_heads(mem @ params["wk"], num_kv_heads, head_dim)
    v = _split_heads(mem @ params["wv"], num_kv_heads, head_dim)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
