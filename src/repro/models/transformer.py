"""Layer blocks and scan-over-layers stacks for every assigned family.

All stacks scan over STACKED per-layer params (leading axis = layer) so HLO
size and compile time are independent of depth. Heterogeneous local/global
attention patterns ride along as a scanned boolean ``is_global`` vector, so
the scan body stays homogeneous (DESIGN.md §7).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.hints import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import Params, init_mlp, init_rmsnorm, mlp, rmsnorm

Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> Params:
    """One decoder layer's params for any family."""
    keys = jax.random.split(key, 6)
    p: Params = {"ln1": init_rmsnorm(cfg.d_model, dtype),
                 "ln2": init_rmsnorm(cfg.d_model, dtype)}
    hd = cfg.resolved_head_dim
    if cfg.arch_type != "ssm":
        p["attn"] = attn.init_attention(
            keys[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype)
    if cfg.arch_type == "ssm":
        p["mamba"] = ssm_lib.init_mamba(keys[1], cfg.d_model, cfg.ssm, dtype)
    elif cfg.arch_type == "hybrid":
        p["mamba"] = ssm_lib.init_mamba(keys[1], cfg.d_model, cfg.ssm, dtype)
        p["mlp"] = init_mlp(keys[2], cfg.d_model, cfg.d_ff, dtype)
    elif cfg.has_moe:
        p["moe"] = moe_lib.init_moe(keys[2], cfg.d_model, cfg.d_ff, cfg.moe, dtype)
    else:
        p["mlp"] = init_mlp(keys[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_stacked_blocks(key, cfg: ModelConfig, dtype, num_layers=None) -> Params:
    L = num_layers if num_layers is not None else cfg.num_layers
    keys = jax.random.split(key, L)
    return jax.vmap(lambda k: init_block(k, cfg, dtype))(keys)


# ---------------------------------------------------------------------------
# per-layer forward (full-sequence)
# ---------------------------------------------------------------------------

def _mixer_full(p: Params, h: jax.Array, cfg: ModelConfig, is_global) -> jax.Array:
    """Token mixer (attention and/or SSM) on the normed input, full sequence."""
    hd = cfg.resolved_head_dim
    if cfg.arch_type == "ssm":
        return ssm_lib.ssd_chunked(p["mamba"], h, cfg.ssm)
    a = attn.attention_full(
        p["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=hd, rope_theta=cfg.rope_theta, is_global=is_global,
        window=cfg.window_size, causal=True,
        use_rope=(cfg.arch_type != "audio"))
    if cfg.arch_type == "hybrid":
        s = ssm_lib.ssd_chunked(p["mamba"], h, cfg.ssm)
        # Hymba fuses the parallel attention and SSM head outputs by mean
        return 0.5 * (a + s)
    return a


def block_full(p: Params, x: jax.Array, cfg: ModelConfig, is_global
               ) -> Tuple[jax.Array, jax.Array]:
    """Full-seq layer: returns (y, moe_aux_loss)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + _mixer_full(p, h, cfg, is_global)
    aux = jnp.zeros((), jnp.float32)
    if cfg.arch_type == "ssm":
        return x, aux
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.has_moe and cfg.arch_type != "hybrid":
        B, S, d = h2.shape
        y, aux = moe_lib.moe_ffn(p["moe"], h2.reshape(B * S, d), cfg.moe)
        y = y.reshape(B, S, d)
    else:
        y = mlp(p["mlp"], h2)
    return x + y, aux


# ---------------------------------------------------------------------------
# decoder stack (scan over layers), full-sequence mode
# ---------------------------------------------------------------------------

def stack_full(stacked: Params, x: jax.Array, cfg: ModelConfig,
               flags: jax.Array, remat: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """Run all layers. flags: [L] bool (is_global). Returns (y, aux_sum)."""

    def body(carry, layer):
        x, aux = carry
        p, flag = layer
        y, a = block_full(p, constrain(x, "btd"), cfg, flag)
        return (constrain(y, "btd"), aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (y, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (stacked, flags))
    return y, aux


# ---------------------------------------------------------------------------
# decoder stack, prefill mode: full-seq forward that also emits the cache
# ---------------------------------------------------------------------------

def _project_kv(p: Params, h: jax.Array, cfg: ModelConfig,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    hd = cfg.resolved_head_dim
    k = (h @ p["attn"]["wk"]).reshape(h.shape[0], h.shape[1], cfg.num_kv_heads, hd)
    v = (h @ p["attn"]["wv"]).reshape(h.shape[0], h.shape[1], cfg.num_kv_heads, hd)
    if cfg.arch_type != "audio":
        from repro.models.layers import apply_rope
        k = apply_rope(k, positions, cfg.rope_theta)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)  # [B,Hkv,S,hd]


def stack_prefill(stacked: Params, x: jax.Array, cfg: ModelConfig,
                  flags: jax.Array) -> Tuple[jax.Array, Cache]:
    """Full forward emitting the per-layer decode cache as scan outputs."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    def body(carry, layer):
        x, aux = carry
        x = constrain(x, "btd")
        p, flag = layer
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        out: Dict[str, jax.Array] = {}
        if cfg.arch_type == "ssm":
            y, st = ssm_lib.ssd_chunked(p["mamba"], h, cfg.ssm, return_state=True)
            out["conv"], out["h"] = st["conv"], st["h"]
            x = x + y
        else:
            out["k"], out["v"] = _project_kv(p, h, cfg, positions)
            a = attn.attention_full(
                p["attn"], h, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta, is_global=flag,
                window=cfg.window_size, causal=True,
                use_rope=(cfg.arch_type != "audio"))
            if cfg.arch_type == "hybrid":
                y, st = ssm_lib.ssd_chunked(p["mamba"], h, cfg.ssm,
                                            return_state=True)
                out["conv"], out["h"] = st["conv"], st["h"]
                a = 0.5 * (a + y)
            x = x + a
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            if cfg.has_moe and cfg.arch_type != "hybrid":
                y2, a2 = moe_lib.moe_ffn(p["moe"], h2.reshape(B * S, -1), cfg.moe)
                x = x + y2.reshape(h2.shape)
                aux = aux + a2
            else:
                x = x + mlp(p["mlp"], h2)
            return (x, aux), out
        return (x, aux), out

    (y, _aux), cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked, flags))
    return y, cache


# ---------------------------------------------------------------------------
# decoder stack, single-token decode mode
# ---------------------------------------------------------------------------

def stack_decode(stacked: Params, x: jax.Array, cache: Cache, pos: jax.Array,
                 cfg: ModelConfig, flags: jax.Array
                 ) -> Tuple[jax.Array, Cache]:
    """One-token decode through all layers, updating the cache.

    The stacked cache rides in the scan CARRY (not xs→ys): while-loop state
    aliases in place, so each layer's update is one dynamic-update-slice
    into the donated buffer. Stacking updated caches as scan outputs makes
    XLA rebuild the full [L, ...] buffer every iteration (§Perf L3:
    327 GB/step of stacked-cache copies measured on llama4 decode_32k).
    """
    hd = cfg.resolved_head_dim
    L = cfg.num_layers

    def body(carry, layer):
        x, cstack = carry
        x = constrain(x, "btd")
        p, flag, li = layer
        c = {k: jax.lax.dynamic_index_in_dim(v, li, 0, keepdims=False)
             for k, v in cstack.items()}
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        new_c: Dict[str, jax.Array] = {}
        if cfg.arch_type == "ssm":
            y, st = ssm_lib.ssd_decode_step(
                p["mamba"], h, {"conv": c["conv"], "h": c["h"]}, cfg.ssm)
            new_c.update(st)
            x = x + y
        else:
            kw = dict(num_heads=cfg.num_heads,
                      num_kv_heads=cfg.num_kv_heads, head_dim=hd,
                      rope_theta=cfg.rope_theta, is_global=flag,
                      window=cfg.window_size,
                      use_rope=(cfg.arch_type != "audio"))
            if "k_scale" in c:   # int8 KV cache (§Perf K1)
                a, nk, nv, nks, nvs = attn.attention_decode(
                    p["attn"], h, c["k"], c["v"], pos,
                    k_scale=c["k_scale"], v_scale=c["v_scale"], **kw)
                new_c["k_scale"], new_c["v_scale"] = nks, nvs
            else:
                a, nk, nv = attn.attention_decode(
                    p["attn"], h, c["k"], c["v"], pos, **kw)
            new_c["k"], new_c["v"] = nk, nv
            if cfg.arch_type == "hybrid":
                y, st = ssm_lib.ssd_decode_step(
                    p["mamba"], h, {"conv": c["conv"], "h": c["h"]}, cfg.ssm)
                new_c.update(st)
                a = 0.5 * (a + y)
            x = x + a
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            if cfg.has_moe and cfg.arch_type != "hybrid":
                B = h2.shape[0]
                y2, _ = moe_lib.moe_ffn(p["moe"], h2.reshape(B, -1), cfg.moe)
                x = x + y2.reshape(h2.shape)
            else:
                x = x + mlp(p["mlp"], h2)
        cstack = {k: jax.lax.dynamic_update_index_in_dim(
            cstack[k], new_c[k].astype(cstack[k].dtype), li, 0)
            for k in cstack}
        return (x, cstack), None

    (y, new_cache), _ = jax.lax.scan(
        body, (x, cache), (stacked, flags, jnp.arange(L)))
    return y, new_cache


# ---------------------------------------------------------------------------
# encoder stack (whisper) — bidirectional, no cache
# ---------------------------------------------------------------------------

def init_encoder_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim,
                                    dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def encoder_stack(stacked: Params, x: jax.Array, cfg: ModelConfig,
                  remat: bool = False) -> jax.Array:
    def body(x, p):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.attention_full(
            p["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, causal=False, use_rope=False)
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["mlp"], h2), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    y, _ = jax.lax.scan(body, x, stacked)
    return y


# ---------------------------------------------------------------------------
# whisper decoder stack: self-attn + cross-attn + mlp
# ---------------------------------------------------------------------------

def init_decoder_block_encdec(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "ln_cross": init_rmsnorm(cfg.d_model, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim,
                                    dtype),
        "cross": attn.init_attention(k2, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.resolved_head_dim,
                                     dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def encdec_decoder_full(stacked: Params, x: jax.Array, mem: jax.Array,
                        cfg: ModelConfig, with_cache: bool = False,
                        remat: bool = False):
    """Whisper decoder full-seq forward; optionally emits the decode cache
    (self K/V from the prompt + cross K/V from the encoder memory)."""
    hd = cfg.resolved_head_dim

    def body(x, p):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        out: Dict[str, jax.Array] = {}
        if with_cache:
            k = (h @ p["attn"]["wk"]).reshape(
                h.shape[0], h.shape[1], cfg.num_kv_heads, hd)
            v = (h @ p["attn"]["wv"]).reshape(
                h.shape[0], h.shape[1], cfg.num_kv_heads, hd)
            out["k"] = k.transpose(0, 2, 1, 3)
            out["v"] = v.transpose(0, 2, 1, 3)
        x = x + attn.attention_full(
            p["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
            rope_theta=cfg.rope_theta, causal=True, use_rope=False)
        hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        km, vm = attn.project_memory_kv(p["cross"], mem,
                                        num_kv_heads=cfg.num_kv_heads,
                                        head_dim=hd)
        if with_cache:
            out["cross_k"], out["cross_v"] = km, vm
        x = x + attn.attention_cross(p["cross"], hc, km, vm,
                                     num_heads=cfg.num_heads,
                                     num_kv_heads=cfg.num_kv_heads, head_dim=hd)
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["mlp"], h2), out

    if remat and not with_cache:
        body = jax.checkpoint(body, prevent_cse=False)
    y, cache = jax.lax.scan(body, x, stacked)
    if with_cache:
        return y, cache
    return y


def encdec_decoder_decode(stacked: Params, x: jax.Array, cache: Cache,
                          pos: jax.Array, cfg: ModelConfig
                          ) -> Tuple[jax.Array, Cache]:
    """One-token whisper decode; cache: k/v (self) + cross_k/cross_v (fixed)."""
    hd = cfg.resolved_head_dim

    def body(x, layer):
        p, c = layer
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, nk, nv = attn.attention_decode(
            p["attn"], h, c["k"], c["v"], pos, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
            rope_theta=cfg.rope_theta, use_rope=False)
        x = x + a
        hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        x = x + attn.attention_cross(p["cross"], hc, c["cross_k"], c["cross_v"],
                                     num_heads=cfg.num_heads,
                                     num_kv_heads=cfg.num_kv_heads, head_dim=hd)
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(p["mlp"], h2)
        return x, {"k": nk, "v": nv, "cross_k": c["cross_k"],
                   "cross_v": c["cross_v"]}

    y, new_cache = jax.lax.scan(body, x, (stacked, cache))
    return y, new_cache
