"""Model facade: embedding + stack + LM head, with train / prefill / decode
entry points for every assigned family, plus ``input_specs`` used by the
multi-pod dry-run (ShapeDtypeStruct stand-ins, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.distributed.hints import constrain
from repro.models import transformer as tfm
from repro.models.layers import (Params, embed_init, init_rmsnorm, rmsnorm,
                                 sinusoidal_positions, softmax_cross_entropy)

Cache = Dict[str, Any]


class Model:
    """Functional model wrapper for one ``ModelConfig``.

    All methods are pure functions of (params, inputs) and jit-able; the
    class only holds static configuration.
    """

    def __init__(self, config: ModelConfig, param_dtype=jnp.bfloat16,
                 remat: bool = False, kv_quant: bool = False):
        self.cfg = config
        self.dtype = param_dtype
        self.remat = remat
        # int8 KV cache (§Perf K1) — decoder-only attention caches
        self.kv_quant = kv_quant and config.arch_type not in ("ssm", "audio")

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_blocks, k_head, k_enc, k_extra = jax.random.split(rng, 5)
        params: Params = {
            "embed": embed_init(k_emb, (cfg.padded_vocab, cfg.d_model), self.dtype),
            "final_norm": init_rmsnorm(cfg.d_model, self.dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(
                k_head, (cfg.d_model, cfg.padded_vocab), self.dtype)
        if cfg.is_encdec:
            params["enc_blocks"] = jax.vmap(
                lambda k: tfm.init_encoder_block(k, cfg, self.dtype)
            )(jax.random.split(k_enc, cfg.num_encoder_layers))
            params["enc_norm"] = init_rmsnorm(cfg.d_model, self.dtype)
            params["blocks"] = jax.vmap(
                lambda k: tfm.init_decoder_block_encdec(k, cfg, self.dtype)
            )(jax.random.split(k_blocks, cfg.num_layers))
        else:
            params["blocks"] = tfm.init_stacked_blocks(k_blocks, cfg, self.dtype)
        if cfg.arch_type == "vlm":
            # projector stub: patch embeddings arrive pre-projected; keep a
            # learned scale so the projector path has params end-to-end.
            params["patch_scale"] = jnp.ones((cfg.d_model,), self.dtype)
        return params

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _flags(self) -> jax.Array:
        return jnp.asarray(self.cfg.global_layer_flags())

    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        x = params["embed"][tokens]
        return x * jnp.asarray(jnp.sqrt(self.cfg.d_model), x.dtype)

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        x = rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["unembed"]

    def _encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper encoder over stubbed frame embeddings [B, T, d]."""
        pos = sinusoidal_positions(frames.shape[1], self.cfg.d_model)
        x = frames + pos[None].astype(frames.dtype)
        x = tfm.encoder_stack(params["enc_blocks"], x, self.cfg,
                              remat=self.remat)
        return rmsnorm(x, params["enc_norm"], self.cfg.norm_eps)

    def _decoder_input(self, params: Params, batch: Dict[str, jax.Array]
                       ) -> jax.Array:
        """Build the decoder-stack input embedding for this family."""
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        if cfg.arch_type == "vlm":
            patches = batch["patch_embeds"].astype(x.dtype)
            patches = patches * params["patch_scale"]
            x = jnp.concatenate([patches, x], axis=1)
        if cfg.is_encdec:
            pos = sinusoidal_positions(x.shape[1], cfg.d_model)
            x = x + pos[None].astype(x.dtype)
        return constrain(x, "btd")

    # ------------------------------------------------------------------
    # training forward / loss
    # ------------------------------------------------------------------
    def forward(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward. Returns (logits, moe_aux_loss)."""
        y, aux = self._hidden(params, batch)
        return self._logits(params, y), aux

    # sequence-chunk size for the CE loss: never materialize [B, S, V]
    LOSS_CHUNK = 512

    def _hidden(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward up to the final hidden states."""
        cfg = self.cfg
        x = self._decoder_input(params, batch)
        if cfg.is_encdec:
            mem = self._encode(params, batch["frames"])
            y = tfm.encdec_decoder_full(params["blocks"], x, mem, cfg,
                                        remat=self.remat)
            aux = jnp.zeros((), jnp.float32)
        else:
            y, aux = tfm.stack_full(params["blocks"], x, cfg, self._flags(),
                                    remat=self.remat)
        return y, aux

    def _chunked_ce(self, params: Params, y: jax.Array, labels: jax.Array,
                    mask: Optional[jax.Array]) -> jax.Array:
        """CE over sequence chunks — logits live one [B, c, V] slab at a
        time (rematerialized in backward), essential for 256k vocabularies."""
        B, S, _ = y.shape
        c = min(self.LOSS_CHUNK, S)
        if S % c:
            c = S  # irregular smoke shapes: single chunk
        nc = S // c
        yc = y.reshape(B, nc, c, -1).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, c).transpose(1, 0, 2)
        mc = (mask if mask is not None
              else jnp.ones((B, S), jnp.float32)).reshape(
                  B, nc, c).transpose(1, 0, 2)

        def body(carry, inp):
            ych, lch, mch = inp
            logits = self._logits(params, constrain(ych, "btd"))
            logits = logits.astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lch[..., None],
                                       axis=-1)[..., 0]
            nll = (logz - gold) * mch
            return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mch)), None

        body = jax.checkpoint(body, prevent_cse=False)
        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (yc, lc, mc))
        return tot / jnp.maximum(cnt, 1.0)

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        y, aux = self._hidden(params, batch)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.arch_type == "vlm":
            # image-patch positions carry no next-token target
            P = cfg.num_patch_tokens
            pad = jnp.zeros(labels.shape[:1] + (P,), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
            m = jnp.concatenate(
                [jnp.zeros(labels.shape[:1] + (P,), jnp.float32),
                 jnp.ones(batch["labels"].shape, jnp.float32)], axis=1)
            mask = m if mask is None else mask * m
        ce = self._chunked_ce(params, y, labels, mask)
        if cfg.has_moe:
            ce = ce + cfg.moe.aux_loss_weight * aux
        return ce

    # ------------------------------------------------------------------
    # serving: prefill + decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int,
                   enc_len: Optional[int] = None) -> Cache:
        """Zeroed decode cache with room for ``seq_len`` positions."""
        cfg = self.cfg
        L, hd = cfg.num_layers, cfg.resolved_head_dim
        cache: Cache = {"pos": jnp.zeros((batch,), jnp.int32)}
        layers: Dict[str, jax.Array] = {}
        if cfg.arch_type != "ssm":
            kv_dtype = jnp.int8 if self.kv_quant else self.dtype
            layers["k"] = jnp.zeros((L, batch, cfg.num_kv_heads, seq_len, hd),
                                    kv_dtype)
            layers["v"] = jnp.zeros((L, batch, cfg.num_kv_heads, seq_len, hd),
                                    kv_dtype)
            if self.kv_quant:
                layers["k_scale"] = jnp.zeros(
                    (L, batch, cfg.num_kv_heads, seq_len, 1), self.dtype)
                layers["v_scale"] = jnp.zeros(
                    (L, batch, cfg.num_kv_heads, seq_len, 1), self.dtype)
        if cfg.has_ssm:
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            H = s.num_heads(cfg.d_model)
            layers["conv"] = jnp.zeros(
                (L, batch, s.d_conv - 1, d_inner + 2 * s.d_state), self.dtype)
            layers["h"] = jnp.zeros((L, batch, H, s.head_dim, s.d_state),
                                    jnp.float32)
        if cfg.is_encdec:
            T = enc_len or cfg.encoder_seq_len
            layers["cross_k"] = jnp.zeros((L, batch, cfg.num_kv_heads, T, hd),
                                          self.dtype)
            layers["cross_v"] = jnp.zeros((L, batch, cfg.num_kv_heads, T, hd),
                                          self.dtype)
        cache["layers"] = layers
        return cache

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                cache_len: int) -> Tuple[jax.Array, Cache]:
        """Process the prompt; return (last-position logits, filled cache).

        The returned cache arrays are sized to the prompt; serving pads them
        into a ``cache_len`` decode cache (see serving/engine.py).
        """
        cfg = self.cfg
        x = self._decoder_input(params, batch)
        B, S, _ = x.shape
        if cfg.is_encdec:
            mem = self._encode(params, batch["frames"])
            y, layers = tfm.encdec_decoder_full(params["blocks"], x, mem, cfg,
                                                with_cache=True)
            pad = cache_len - S
            layers["k"] = jnp.pad(layers["k"],
                                  ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
            layers["v"] = jnp.pad(layers["v"],
                                  ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        else:
            y, layers = tfm.stack_prefill(params["blocks"], x, cfg,
                                          self._flags())
            if "k" in layers:
                pad = cache_len - S
                layers["k"] = jnp.pad(layers["k"],
                                      ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
                layers["v"] = jnp.pad(layers["v"],
                                      ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        if self.kv_quant and "k" in layers:
            from repro.models.kvquant import quantize
            layers["k"], layers["k_scale"] = quantize(
                layers["k"], scale_dtype=self.dtype)
            layers["v"], layers["v_scale"] = quantize(
                layers["v"], scale_dtype=self.dtype)
        logits = self._logits(params, y[:, -1:])
        cache = {"pos": jnp.full((B,), S, jnp.int32), "layers": layers}
        return logits, cache

    def decode_step(self, params: Params, tokens: jax.Array, cache: Cache
                    ) -> Tuple[jax.Array, Cache]:
        """One decode step. tokens: [B, 1] -> (logits [B,1,V], new cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        pos = cache["pos"]
        if cfg.is_encdec:
            # absolute sinusoidal position for each row's new token
            table = sinusoidal_positions(cache["layers"]["k"].shape[3],
                                         cfg.d_model)
            B = tokens.shape[0]
            posv = jnp.broadcast_to(jnp.asarray(pos), (B,))
            x = x + table[posv][:, None].astype(x.dtype)
            y, layers = tfm.encdec_decoder_decode(params["blocks"], x,
                                                  cache["layers"], pos, cfg)
        else:
            y, layers = tfm.stack_decode(params["blocks"], x, cache["layers"],
                                         pos, cfg, self._flags())
        logits = self._logits(params, y)
        return logits, {"pos": pos + 1, "layers": layers}

    # ------------------------------------------------------------------
    # dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
    # ------------------------------------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        """Abstract inputs for the step selected by ``shape.kind``."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f = self.dtype

        def tok(b, s):
            return jax.ShapeDtypeStruct((b, s), i32)

        if shape.kind == "train":
            specs: Dict[str, Any] = {}
            s_text = S - cfg.num_patch_tokens if cfg.arch_type == "vlm" else S
            specs["tokens"] = tok(B, s_text)
            specs["labels"] = tok(B, s_text if cfg.arch_type == "vlm" else S)
            if cfg.arch_type == "vlm":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_patch_tokens, cfg.d_model), f)
            if cfg.is_encdec:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq_len, cfg.d_model), f)
            return specs
        if shape.kind == "prefill":
            specs = {}
            s_text = S - cfg.num_patch_tokens if cfg.arch_type == "vlm" else S
            specs["tokens"] = tok(B, s_text)
            if cfg.arch_type == "vlm":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_patch_tokens, cfg.d_model), f)
            if cfg.is_encdec:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq_len, cfg.d_model), f)
            return specs
        # decode: one token against a cache holding ``seq_len`` positions
        cache = jax.eval_shape(
            lambda: self.init_cache(B, S,
                                    enc_len=cfg.encoder_seq_len or None))
        return {"tokens": tok(B, 1), "cache": cache}
