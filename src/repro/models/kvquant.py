"""int8 KV-cache quantization (beyond-paper serving feature, §Perf K1).

Decode is KV-bandwidth-bound (every roofline decode row is memory-term
dominant), so halving cache bytes halves the dominant term. Scheme:
symmetric per-(position, head) int8 with an fp16-ish scale stored alongside
— the standard serving-stack layout (scale axis = the last dim, which is
where the dot contracts, so dequantization fuses into the QK/PV einsums).

  quantize:   scale = max|x| / 127 over head_dim;  q = round(x / scale)
  dequantize: x ≈ q * scale

Exposed through ``Model(..., kv_quant=True)``: ``init_cache`` stores
``k/v`` as int8 plus ``k_scale/v_scale`` bf16; attention dequantizes on
read. Accuracy is validated in tests (logit error ~1e-2, rank-1 agreement
on smoke models).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, scale_dtype=jnp.bfloat16
             ) -> Tuple[jax.Array, jax.Array]:
    """x: [..., hd] -> (int8 [..., hd], scale [..., 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(scale_dtype)


def dequantize(q: jax.Array, scale: jax.Array,
               dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)
