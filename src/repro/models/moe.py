"""Mixture-of-experts FFN with sort-based capacity dispatch.

Design notes (these matter for the roofline):

* Dispatch is SORT-based, not one-hot-einsum based. GShard-style one-hot
  dispatch materializes a [tokens, experts, capacity] tensor and burns
  T·E·C·d MAC flops on bookkeeping — at llama4-maverick train_4k scale that
  is ~1e16 "fake" flops, an order of magnitude more than the model itself,
  which would destroy the MODEL_FLOPS/HLO_FLOPs usefulness ratio reported in
  EXPERIMENTS.md. Sorting + scatter/gather keeps bookkeeping in the memory
  term where it belongs.

* Expert compute is a grouped GEMM over a dense [E, C, d] buffer — exactly
  the superkernel population the paper's coalescer targets (DESIGN.md §5);
  the serving engine routes these through the coalesced_gemm Pallas kernel.

* Tokens beyond an expert's capacity C = ceil(T·top_k/E · capacity_factor)
  are dropped (standard GShard semantics); the combine step zeroes their
  contribution so the residual stream still carries them.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.hints import constrain
from repro.models.layers import Params, dense_init


def init_moe(key, d_model: int, d_ff: int, cfg: MoEConfig, dtype) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    E = cfg.num_experts
    return {
        "router": dense_init(kr, (d_model, E), jnp.float32),
        "w_gate": dense_init(kg, (E, d_model, d_ff), dtype),
        "w_up": dense_init(ku, (E, d_model, d_ff), dtype),
        "w_down": dense_init(kd, (E, d_ff, d_model), dtype),
    }


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(c, cfg.top_k)


def route(router: jax.Array, x: jax.Array, cfg: MoEConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. x: [T, d] -> (weights [T,k], experts [T,k], aux_loss)."""
    logits = (x.astype(jnp.float32) @ router)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    T = x.shape[0]
    one_hot = jax.nn.one_hot(experts[:, 0], cfg.num_experts, dtype=jnp.float32)
    frac = jnp.mean(one_hot, axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = cfg.num_experts * jnp.sum(frac * mean_p)
    return weights, experts, aux


def _dispatch(x: jax.Array, weights: jax.Array, experts: jax.Array,
              E: int, k: int, C: int):
    """Sort-based dispatch of one token group. x: [T, d]."""
    T, d = x.shape
    e_flat = experts.reshape(-1)                       # [T*k]
    tok_of = jnp.arange(T * k) // k                    # assignment -> token
    order = jnp.argsort(e_flat, stable=True)           # [T*k]
    sorted_e = e_flat[order]
    sorted_tok = tok_of[order]
    # rank of each assignment within its expert
    counts = jnp.zeros((E,), jnp.int32).at[sorted_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k, dtype=jnp.int32) - offsets[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, rank, C)                    # C is out-of-bounds
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[sorted_e, slot].set(x[sorted_tok], mode="drop")
    return buf, (order, sorted_e, sorted_tok, keep, slot)


def _combine(out_buf: jax.Array, w_flat: jax.Array, meta, T: int, d: int
             ) -> jax.Array:
    order, sorted_e, sorted_tok, keep, slot = meta
    gathered = out_buf[sorted_e, jnp.where(keep, slot, 0)]    # [T*k, d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered.astype(jnp.float32) * w_flat[order][:, None]
    return jnp.zeros((T, d), jnp.float32).at[sorted_tok].add(contrib)


# Public per-stage seams for the JIT's MoE decode template (core/jit.py
# ``build_moe_decode_template``): the sort-based dispatch and the weighted
# combine are exposed under stable names so the staged path runs EXACTLY the
# same bookkeeping code as the monolithic ``moe_ffn`` (one copy of the
# capacity/drop semantics), with only the three expert einsums replaced by
# declared per-expert GemmStages.
dispatch_tokens = _dispatch
combine_tokens = _combine


def expert_ffn_weights(moe_params: Params, e: int
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Expert ``e``'s (w_gate, w_up, w_down) slices of the stacked packs.

    Per-stage weight accessor for the JIT template builder. Callers that
    feed the dispatch executor must call this ONCE (at template build) and
    close over the results: the executor's packed-weight cache guards on
    weight-array identity, so a fresh slice per step would read as a
    phantom hot-swap and repack the expert stack every tick."""
    return (moe_params["w_gate"][e], moe_params["w_up"][e],
            moe_params["w_down"][e])


def moe_ffn(params: Params, x: jax.Array, cfg: MoEConfig,
            groups: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. x: [T, d] -> (y [T, d], aux_loss scalar).

    ``groups`` (default: the launcher's 'moe_groups' hint, else 1) splits
    tokens into independently-routed groups aligned with the data-parallel
    axis (GShard-style). Without grouping the sort/scatter dispatch is
    GLOBAL — under pjit that replicates every token on every chip (measured
    on grok train_4k: a collective-permute of all 2M tokens plus 21.5
    GB/layer activation all-reduces). With groups == data shards, dispatch
    is local; expert-parallel weights (llama4) then produce the canonical
    [G, E, C, d] all-to-all, and replicated-expert weights (grok) need no
    dispatch communication at all.
    """
    from repro.distributed.hints import static_hint
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    G = groups if groups is not None else int(static_hint("moe_groups", 1))
    if T % G:
        G = 1
    Tg = T // G
    C = capacity(Tg, cfg)

    weights, experts, aux = route(params["router"], x, cfg)

    xg = constrain(x.reshape(G, Tg, d), "moe_tokens")
    wg = weights.reshape(G, Tg, k)
    eg = experts.reshape(G, Tg, k)

    buf, meta = jax.vmap(
        lambda xx, ww, ee: _dispatch(xx, ww, ee, E, k, C))(xg, wg, eg)
    buf = constrain(buf, "moe_buf")                     # [G, E, C, d]

    # ---- grouped expert GEMMs (the paper's superkernel population) ----------
    # ZeRO-3 hint (§Perf G1): when experts can't shard over the data axis
    # (grok: 8 experts, 16-way), expert weights are FSDP-sharded on d_model
    # — the CONTRACTION dim — and SPMD would partial-contract + all-reduce
    # the [E, C, d_ff] activations; the hint gathers the (small) weights
    # instead. Set by the launcher only for non-expert-parallel MoE.
    w_gate = constrain(params["w_gate"], "moe_w_col")
    w_up = constrain(params["w_up"], "moe_w_col")
    w_down = constrain(params["w_down"], "moe_w_row")
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w_gate))
    up = jnp.einsum("gecd,edf->gecf", buf, w_up)
    out_buf = jnp.einsum("gecf,efd->gecd", gate * up, w_down)

    # ---- combine back --------------------------------------------------------
    y = jax.vmap(lambda ob, ww, mm: _combine(ob, ww.reshape(-1), mm, Tg, d)
                 )(out_buf, wg, meta)
    y = constrain(y, "moe_tokens")
    return y.reshape(T, d).astype(x.dtype), aux
