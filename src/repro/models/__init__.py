from repro.models.model import Model

__all__ = ["Model"]
