"""repro — a multi-pod JAX reproduction of "The OoO VLIW JIT Compiler for
GPU Inference" (Jain et al., 2019), adapted TPU-native.

Layers (bottom-up): models/ (10-arch zoo) → kernels/ (Pallas superkernels)
→ core/ (the paper: clustering, coalescing, OoO scheduling, autotuning)
→ serving/ + training/ → distributed/ + launch/ (multi-pod dry-run).
"""

__version__ = "0.1.0"
