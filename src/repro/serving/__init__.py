from repro.serving.engine import ServeReport, ServingEngine, Tenant
from repro.serving.workload import (ServeRequest, bursty_arrivals, make_trace,
                                    poisson_arrivals)

__all__ = [
    "ServeReport", "ServeRequest", "ServingEngine", "Tenant",
    "bursty_arrivals", "make_trace", "poisson_arrivals",
]
