from repro.serving.admission import (AdmissionController, AdmissionDecision,
                                     DEFAULT_TIERS, TierSpec)
from repro.serving.engine import (ArrivalPredictor, ServeReport,
                                  ServingEngine, Tenant)
from repro.serving.frontdoor import (DoorClosed, FrontDoor, MonotonicClock,
                                     Ticket, VirtualClock)
from repro.serving.workload import (ServeRequest, bursty_arrivals,
                                    diurnal_arrivals, long_prompt_trace,
                                    make_trace, open_loop_trace,
                                    poisson_arrivals, two_wave_trace)

__all__ = [
    "AdmissionController", "AdmissionDecision", "ArrivalPredictor",
    "DEFAULT_TIERS", "DoorClosed", "FrontDoor", "MonotonicClock",
    "ServeReport", "ServeRequest", "ServingEngine", "Tenant", "Ticket",
    "TierSpec", "VirtualClock",
    "bursty_arrivals", "diurnal_arrivals", "long_prompt_trace", "make_trace",
    "open_loop_trace", "poisson_arrivals", "two_wave_trace",
]
