from repro.serving.engine import (ArrivalPredictor, ServeReport,
                                  ServingEngine, Tenant)
from repro.serving.workload import (ServeRequest, bursty_arrivals,
                                    long_prompt_trace, make_trace,
                                    poisson_arrivals, two_wave_trace)

__all__ = [
    "ArrivalPredictor", "ServeReport", "ServeRequest", "ServingEngine",
    "Tenant",
    "bursty_arrivals", "long_prompt_trace", "make_trace", "poisson_arrivals",
    "two_wave_trace",
]
