"""Synthetic serving workloads: tenants, arrival processes, request traces."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    tenant: str
    arrival_t: float
    prompt_len: int
    max_new_tokens: int
    slo_s: float
    # SLO tier (index into the engine's TierSpec ladder, 0 = most urgent).
    # The front door's admission controller may DEGRADE a request to a
    # lower tier (relaxing slo_s, recording the original in
    # ``degraded_from``) or SHED it outright instead of admitting it.
    tier: int = 0
    # filled by the engine:
    finish_t: float = float("nan")
    tokens_out: Optional[List[int]] = None
    shed: bool = False
    degraded_from: Optional[int] = None

    @property
    def latency(self) -> float:
        return self.finish_t - self.arrival_t

    @property
    def met_slo(self) -> bool:
        # NaN finish_t (unfinished or shed) compares False: a request that
        # never finished did not meet its SLO
        return self.latency <= self.slo_s


def poisson_arrivals(rate_hz: float, n: int, rng: np.random.Generator,
                     start_t: float = 0.0) -> List[float]:
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return list(start_t + np.cumsum(gaps))


def bursty_arrivals(rate_hz: float, n: int, rng: np.random.Generator,
                    burst_factor: float = 5.0, p_burst: float = 0.2
                    ) -> List[float]:
    """MMPP-ish: occasional bursts at ``burst_factor``× the base rate —
    the paper's 'bursty arrival processes' (§7)."""
    out, t = [], 0.0
    for _ in range(n):
        r = rate_hz * (burst_factor if rng.random() < p_burst else 1.0)
        t += rng.exponential(1.0 / r)
        out.append(t)
    return out


def diurnal_arrivals(base_hz: float, peak_hz: float, period_s: float,
                     n: int, rng: np.random.Generator,
                     start_t: float = 0.0) -> List[float]:
    """Nonhomogeneous Poisson arrivals via thinning: the rate swings
    sinusoidally between ``base_hz`` (trough) and ``peak_hz`` (peak) with
    period ``period_s`` — the diurnal load curve the serving front door is
    gated on (time-average rate = (base + peak) / 2)."""
    out: List[float] = []
    t = start_t
    lam_max = max(base_hz, peak_hz)
    while len(out) < n:
        t += rng.exponential(1.0 / lam_max)
        lam = base_hz + (peak_hz - base_hz) * 0.5 * (
            1.0 - np.cos(2.0 * np.pi * (t - start_t) / period_s))
        if rng.random() * lam_max < lam:
            out.append(t)
    return out


def open_loop_trace(tenants: Sequence[str], rate_hz: float, n: int, *,
                    shape: str = "poisson",
                    tier_slo_s: Sequence[float] = (0.002, 0.004, 0.012),
                    tier_weights: Sequence[float] = (0.5, 0.3, 0.2),
                    prompt_len: int = 8, max_new_tokens: int = 4,
                    burst_factor: float = 5.0, period_s: Optional[float] = None,
                    seed: int = 0, rid0: int = 0) -> List[ServeRequest]:
    """Open-loop tiered trace for the serving front door: ONE merged
    arrival stream at ``rate_hz`` (arrivals keep coming regardless of
    completions — the sustained-load regime), split round-robin over
    ``tenants``; each request draws an SLO tier from ``tier_weights``
    (tier i carries deadline ``tier_slo_s[i]``). ``shape`` selects the
    arrival process: "poisson", "bursty" (MMPP) or "diurnal" (sinusoidal
    rate between 0.25x and 1.75x of ``rate_hz``, period ``period_s`` or
    the trace's natural span)."""
    rng = np.random.default_rng(seed)
    if shape == "poisson":
        arr = poisson_arrivals(rate_hz, n, rng)
    elif shape == "bursty":
        arr = bursty_arrivals(rate_hz, n, rng, burst_factor=burst_factor)
    elif shape == "diurnal":
        period = period_s if period_s is not None else n / rate_hz
        arr = diurnal_arrivals(0.25 * rate_hz, 1.75 * rate_hz, period, n,
                               rng)
    else:
        raise ValueError(f"unknown arrival shape {shape!r}")
    w = np.asarray(tier_weights, dtype=float)
    tiers = rng.choice(len(w), size=n, p=w / w.sum())
    return [ServeRequest(rid0 + i, tenants[i % len(tenants)], float(t),
                         prompt_len, max_new_tokens,
                         slo_s=float(tier_slo_s[tier]), tier=int(tier))
            for i, (t, tier) in enumerate(zip(arr, tiers))]


def two_wave_trace(wave1: Sequence[str], wave2: Sequence[str],
                   gap_s: float, *, prompt_len: int = 8,
                   max_new_tokens: int = 8, slo_s: float = 1.0
                   ) -> List[ServeRequest]:
    """Deterministic staged arrivals: one request per ``wave1`` tenant at
    t=0, one per ``wave2`` tenant at t=``gap_s``. The fixture for the
    stagger/WAIT regression tests — wave 2 lands inside wave 1's slack
    window, so an arrival-aware scheduler should delay under-filled
    dispatches to coalesce with it."""
    reqs: List[ServeRequest] = []
    for i, name in enumerate(wave1):
        reqs.append(ServeRequest(i, name, 0.0, prompt_len, max_new_tokens,
                                 slo_s))
    for j, name in enumerate(wave2):
        reqs.append(ServeRequest(len(wave1) + j, name, float(gap_s),
                                 prompt_len, max_new_tokens, slo_s))
    return reqs


def long_prompt_trace(tenants: Sequence[str], *, prompt_len: int = 256,
                      max_new_tokens: int = 4, slo_s: float = 10.0,
                      stagger_s: float = 0.0, n_per_tenant: int = 1,
                      prompt_jitter: int = 0, seed: int = 0
                      ) -> List[ServeRequest]:
    """Deterministic long-prompt multi-tenant trace — the prefill-coalescing
    fixture: every tenant submits ``n_per_tenant`` requests whose prompts
    dominate the work (``prompt_len`` >> ``max_new_tokens``), interleaved
    round-robin ``stagger_s`` apart so several tenants' prompt GEMMs are in
    flight together. ``prompt_jitter`` draws per-request lengths from
    [prompt_len - jitter, prompt_len] to exercise the prefill buckets."""
    rng = np.random.default_rng(seed)
    reqs: List[ServeRequest] = []
    rid = 0
    for wave in range(n_per_tenant):
        for name in tenants:
            plen = int(prompt_len - (rng.integers(0, prompt_jitter + 1)
                                     if prompt_jitter else 0))
            reqs.append(ServeRequest(rid, name, rid * stagger_s, plen,
                                     max_new_tokens, slo_s))
            rid += 1
    return reqs


def make_trace(tenants: Sequence[str], rate_hz: float, n_per_tenant: int,
               *, prompt_len: int = 32, max_new_tokens: int = 8,
               slo_s: float = 0.2, seed: int = 0, bursty: bool = False
               ) -> List[ServeRequest]:
    rng = np.random.default_rng(seed)
    reqs: List[ServeRequest] = []
    rid = 0
    for name in tenants:
        arr_fn = bursty_arrivals if bursty else poisson_arrivals
        for t in arr_fn(rate_hz, n_per_tenant, rng):
            reqs.append(ServeRequest(rid, name, float(t), prompt_len,
                                     max_new_tokens, slo_s))
            rid += 1
    return sorted(reqs, key=lambda r: r.arrival_t)
