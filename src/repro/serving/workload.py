"""Synthetic serving workloads: tenants, arrival processes, request traces."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    tenant: str
    arrival_t: float
    prompt_len: int
    max_new_tokens: int
    slo_s: float
    # filled by the engine:
    finish_t: float = float("nan")
    tokens_out: Optional[List[int]] = None

    @property
    def latency(self) -> float:
        return self.finish_t - self.arrival_t

    @property
    def met_slo(self) -> bool:
        return self.latency <= self.slo_s


def poisson_arrivals(rate_hz: float, n: int, rng: np.random.Generator,
                     start_t: float = 0.0) -> List[float]:
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return list(start_t + np.cumsum(gaps))


def bursty_arrivals(rate_hz: float, n: int, rng: np.random.Generator,
                    burst_factor: float = 5.0, p_burst: float = 0.2
                    ) -> List[float]:
    """MMPP-ish: occasional bursts at ``burst_factor``× the base rate —
    the paper's 'bursty arrival processes' (§7)."""
    out, t = [], 0.0
    for _ in range(n):
        r = rate_hz * (burst_factor if rng.random() < p_burst else 1.0)
        t += rng.exponential(1.0 / r)
        out.append(t)
    return out


def two_wave_trace(wave1: Sequence[str], wave2: Sequence[str],
                   gap_s: float, *, prompt_len: int = 8,
                   max_new_tokens: int = 8, slo_s: float = 1.0
                   ) -> List[ServeRequest]:
    """Deterministic staged arrivals: one request per ``wave1`` tenant at
    t=0, one per ``wave2`` tenant at t=``gap_s``. The fixture for the
    stagger/WAIT regression tests — wave 2 lands inside wave 1's slack
    window, so an arrival-aware scheduler should delay under-filled
    dispatches to coalesce with it."""
    reqs: List[ServeRequest] = []
    for i, name in enumerate(wave1):
        reqs.append(ServeRequest(i, name, 0.0, prompt_len, max_new_tokens,
                                 slo_s))
    for j, name in enumerate(wave2):
        reqs.append(ServeRequest(len(wave1) + j, name, float(gap_s),
                                 prompt_len, max_new_tokens, slo_s))
    return reqs


def long_prompt_trace(tenants: Sequence[str], *, prompt_len: int = 256,
                      max_new_tokens: int = 4, slo_s: float = 10.0,
                      stagger_s: float = 0.0, n_per_tenant: int = 1,
                      prompt_jitter: int = 0, seed: int = 0
                      ) -> List[ServeRequest]:
    """Deterministic long-prompt multi-tenant trace — the prefill-coalescing
    fixture: every tenant submits ``n_per_tenant`` requests whose prompts
    dominate the work (``prompt_len`` >> ``max_new_tokens``), interleaved
    round-robin ``stagger_s`` apart so several tenants' prompt GEMMs are in
    flight together. ``prompt_jitter`` draws per-request lengths from
    [prompt_len - jitter, prompt_len] to exercise the prefill buckets."""
    rng = np.random.default_rng(seed)
    reqs: List[ServeRequest] = []
    rid = 0
    for wave in range(n_per_tenant):
        for name in tenants:
            plen = int(prompt_len - (rng.integers(0, prompt_jitter + 1)
                                     if prompt_jitter else 0))
            reqs.append(ServeRequest(rid, name, rid * stagger_s, plen,
                                     max_new_tokens, slo_s))
            rid += 1
    return reqs


def make_trace(tenants: Sequence[str], rate_hz: float, n_per_tenant: int,
               *, prompt_len: int = 32, max_new_tokens: int = 8,
               slo_s: float = 0.2, seed: int = 0, bursty: bool = False
               ) -> List[ServeRequest]:
    rng = np.random.default_rng(seed)
    reqs: List[ServeRequest] = []
    rid = 0
    for name in tenants:
        arr_fn = bursty_arrivals if bursty else poisson_arrivals
        for t in arr_fn(rate_hz, n_per_tenant, rng):
            reqs.append(ServeRequest(rid, name, float(t), prompt_len,
                                     max_new_tokens, slo_s))
            rid += 1
    return sorted(reqs, key=lambda r: r.arrival_t)
