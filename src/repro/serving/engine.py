"""Multi-tenant serving engine: event-driven OoO serving with live admission.

Three execution modes, mirroring the paper's comparison end-to-end:

  * "time"    — each request decodes alone, requests strictly serialized
                (GPU time-multiplexing, §4.1);
  * "batched" — continuous batching *within* each tenant, tenants serialized
                (ModelBatch / TensorRT-style, §4.2's strongest baseline);
  * "vliw"    — OUR engine: a single virtual-time **event loop** over an
                admission-open ``JitSession`` (core/jit.py). Tenants'
                decode steps AND dense prompt prefills are compiled to
                KernelPrograms and coalesced ACROSS tenants: admission
                *declares* a prefill program (prompt GEMMs enter the live
                op pool, KV write-back is the program epilogue, and the
                tenant's decode joins only after the completion event)
                instead of charging the prompt analytically on the shared
                clock — so a long prompt no longer head-of-line-blocks
                other tenants, it coalesces with them. A request arriving
                mid-flight joins *between superkernel dispatches*, not at
                a round boundary.
                The trace's future arrival times are fed to the OoO
                scheduler, so its stagger/WAIT branch executes for real; the
                tightest per-request deadline of each tenant's batch flows
                into per-op ``latest_start_t`` for EDF anchoring and
                eviction of already-missed stragglers.

In vliw mode the engine can drive an N-device modeled mesh
(``num_devices`` / an explicit ``DeviceSet``): each tenant is bound to a
home device at its FIRST admission (``distributed/placement.py`` — greedy
least-loaded bin-packing over modeled steady-state load) and every op it
ever declares runs on that device's own virtual timeline — one
``JitSession`` (scheduler + coalescer + free instant + EDF anchor set)
per device, all sharing one ``VLIWJit``'s plan/weight caches (keyed with
the device id) and one ``ScheduleTrace``. Ops never coalesce across
devices. Expert-parallel MoE tenants additionally SPAN the mesh with
their expert weights when the mesh size divides the expert count; their
ops stay on the home timeline but carry an all-to-all dispatch/combine
charge in EDF slack and plan estimates.

Arch-support matrix (which path each tenant takes in vliw mode):

  ==========  =====================  ==========================  ===============
  arch_type   decode step            prompt prefill              mesh placement
  ==========  =====================  ==========================  ===============
  dense       KernelProgram          declared prefill program    home device
                                     (>= prefill_declare_min;
                                     analytic below it)
  vlm         KernelProgram          analytic (patch projector)  home device
  moe         KernelProgram          analytic                    home device;
              (router glue +                                     experts span
              per-expert GEMMs)                                  mesh when
                                                                 N | n_experts
                                                                 (+ all-to-all)
  ssm         KernelProgram          analytic                    home device
              (scan recurrence glue)
  hybrid      monolithic batched     analytic                    home device
  audio       monolithic batched     analytic                    home device
  int8-KV     monolithic batched     analytic                    home device
  (any arch)
  ==========  =====================  ==========================  ===============

KernelProgram rows flow through admission → EDF scheduling → clustering →
coalesced dispatch (``JitStats.nondense_programs`` counts the MoE/SSM
ones); "monolithic batched" rows run ``Model.decode_step`` inside the same
event loop, serialized on the virtual clock. Baseline modes ("time",
"batched") always run monolithic steps — that asymmetry IS the experiment.

The baseline modes keep their defining round-synchronous semantics
(``_run_rounds``); greedy tokens are asserted identical across all three
modes because batch rows are independent, so scheduling order cannot change
any request's token stream.

Token generation is REAL (greedy argmax through the actual models); time is
attributed with the calibrated device cost model, since wall-clock on a CPU
host says nothing about TPU latency. Both are reported.

Continuous batching mechanics: each tenant owns a slotted decode cache
(``max_batch`` rows, per-row positions). Admission prefills a request
(real ``Model.prefill``) and writes its KV rows into a free slot; completed
requests free their slot mid-flight — per-row ``pos`` makes mixed-depth
batches correct (models/attention.py).

The front door (daemon mode + admission control)
------------------------------------------------

``engine.run`` replays a finite trace in virtual time and terminates when
it is exhausted. ``engine.serve_forever(door)`` is the production front
door: a long-lived loop over the SAME per-device event-loop machinery
that accepts continuous admission from a ``FrontDoor`` on a real clock
(``serving/frontdoor.py``), streams each request's tokens out as they
retire (``token_sink`` / per-request ``Ticket``), IDLES while the door is
open and empty (the replay stall guard becomes a wait), and flushes
in-flight work then terminates cleanly once the door closes.

Real-clock vs virtual-time semantics: with an authoritative clock
(``MonotonicClock``, the default) the per-device virtual timelines are
floored at real elapsed time each iteration, so arrival stamps, SLO
deadlines and modeled service charges share one axis. With a follower
``VirtualClock`` (tests / the sustained-load bench) the clock tracks the
modeled timelines instead and a pre-scheduled door replays exactly like
``run`` — bit-identical tokens on the admitted set.

Admission control (``admission_control=True`` or an explicit
``AdmissionController``): every request carries a priority/SLO ``tier``
(serving/admission.py's ``TierSpec`` ladder), and when it becomes due the
door makes an explicit decision from the analytic cost model — forecast
completion = now + committed device backlog + modeled request cost + an
overload margin from the ``ArrivalPredictor`` load forecast. A request
whose tier deadline is infeasible is DEGRADED down the ladder (relaxed
deadline it can actually keep, ``degraded_from`` records the original
tier) or SHED at the door — so under overload accepted requests keep
their deadlines instead of every request degrading together. Shed
requests never occupy a slot; they count as SLO misses in
``ServeReport.slo_attainment`` and per-tier attainment (never silently
vanishing into ``unfinished``). The same admission path runs under
``run`` for deterministic open-loop replay benches
(benchmarks/e2e_slo_attainment.py gates admission-on vs admit-everything).
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.certify import ScheduleCertifier, check_conservation
from repro.configs.base import ModelConfig
from repro.core.costmodel import CostModel, GemmShape, TPUV5E
from repro.core.jit import (JitStats, KernelProgram, VLIWJit,
                            build_dense_decode_template,
                            build_dense_prefill_template,
                            build_moe_decode_template,
                            build_ssm_decode_template,
                            dense_program_cache_key, moe_program_cache_key,
                            prefill_bucket, prefill_program_cache_key,
                            ssm_program_cache_key)
from repro.core.kernelspec import gemm_population
from repro.core.scheduler import SchedulerConfig
from repro.core.schedtrace import ScheduleTrace
from repro.distributed.placement import DeviceSet, PlacementPolicy
from repro.models.model import Model
from repro.serving.admission import AdmissionController, DEFAULT_TIERS
from repro.serving.frontdoor import FrontDoor, MonotonicClock
from repro.serving.workload import ServeRequest


@dataclasses.dataclass
class Tenant:
    name: str
    model: Model
    params: Any
    cache_len: int = 64
    max_batch: int = 4
    # runtime state
    cache: Any = None
    slot_req: List[Optional[ServeRequest]] = dataclasses.field(
        default_factory=list)
    slot_tok: Any = None
    slot_remaining: List[int] = dataclasses.field(default_factory=list)

    @property
    def cfg(self) -> ModelConfig:
        return self.model.cfg

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]


@dataclasses.dataclass
class ServeReport:
    mode: str
    requests: List[ServeRequest]
    modeled_time_s: float
    wall_time_s: float
    jit: Optional[JitStats] = None
    # multi-device vliw runs only (None otherwise): index d = mesh slot d
    device_time_s: Optional[List[float]] = None   # final per-device clock
    device_busy_s: Optional[List[float]] = None   # modeled busy time charged

    @property
    def num_devices(self) -> int:
        return len(self.device_time_s) if self.device_time_s else 1

    @property
    def device_util(self) -> List[float]:
        """Per-device busy fraction of the fleet makespan — the utilization
        skew the placement policy is judged on."""
        if not self.device_busy_s or not self.modeled_time_s:
            return []
        return [b / self.modeled_time_s for b in self.device_busy_s]

    @property
    def device_skew(self) -> float:
        """max/mean per-device busy time; 1.0 = perfectly balanced."""
        if not self.device_busy_s:
            return 1.0
        mean = sum(self.device_busy_s) / len(self.device_busy_s)
        return max(self.device_busy_s) / mean if mean > 0 else 1.0

    @property
    def finished(self) -> List[ServeRequest]:
        return [r for r in self.requests if not np.isnan(r.finish_t)]

    @property
    def unfinished(self) -> int:
        """Requests that never finished (shed / dropped / stalled /
        unadmittable). Exposed so latency stats restricted to finished
        requests cannot silently hide drops."""
        return len(self.requests) - len(self.finished)

    @property
    def shed(self) -> int:
        """Requests the front door refused at admission (a subset of
        ``unfinished``; they count as SLO misses, see below)."""
        return sum(1 for r in self.requests if r.shed)

    @property
    def slo_attainment(self) -> float:
        """Fraction of ALL requests that finished within their SLO.

        The denominator is every request — shed and unfinished requests
        count as misses (``met_slo`` is False on a NaN finish). They used
        to be excluded entirely, which silently inflated attainment the
        moment the front door shed or dropped anything. NOTE the
        deliberate asymmetry with ``mean_latency``: attainment is a
        promise-keeping ratio (a drop is a broken promise), while a mean
        over latencies that include NaN/inf drops would be meaningless —
        so the mean stays finished-only, with ``unfinished``/``shed``
        published alongside it."""
        n = len(self.requests)
        return sum(r.met_slo for r in self.requests) / max(n, 1)

    def tier_attainment(self, original: bool = True) -> Dict[int, float]:
        """Per-tier SLO attainment (shed/unfinished count as misses).
        ``original=True`` groups a degraded request under the tier it
        ARRIVED with (the door's promise ledger); ``original=False``
        groups by the tier it was served at."""
        def tier_of(r: ServeRequest) -> int:
            if original and r.degraded_from is not None:
                return r.degraded_from
            return r.tier
        out: Dict[int, List[ServeRequest]] = {}
        for r in self.requests:
            out.setdefault(tier_of(r), []).append(r)
        return {tier: sum(r.met_slo for r in grp) / len(grp)
                for tier, grp in sorted(out.items())}

    @property
    def goodput_rps(self) -> float:
        """SLO-met completions per modeled second — the front-door
        acceptance metric: past the saturation knee an admit-everything
        policy keeps its throughput but loses its goodput."""
        met = sum(r.met_slo for r in self.requests)
        return met / self.modeled_time_s if self.modeled_time_s else 0.0

    @property
    def mean_latency(self) -> float:
        """Mean latency over FINISHED requests only — an unfinished request
        has finish_t = NaN, which used to poison the whole mean. Check
        ``unfinished`` / ``shed`` to see how many were excluded (attainment
        and ``p_latency`` DO count them; see ``slo_attainment``)."""
        done = self.finished
        return float(np.mean([r.latency for r in done])) if done \
            else float("nan")

    def p_latency(self, q: float) -> float:
        """Latency quantile over ALL requests: an unfinished or shed
        request contributes +inf (it never completed), so tail percentiles
        reflect drops instead of silently excluding them. Computed by
        explicit linear-interpolation rank (np.quantile's interpolation
        through inf produces NaN); matches np.quantile when every request
        finished. NaN when the report is empty."""
        n = len(self.requests)
        if n == 0:
            return float("nan")
        lats = sorted(r.latency for r in self.finished)
        k = len(lats)
        pos = q * (n - 1)
        lo, hi = int(math.floor(pos)), int(math.ceil(pos))
        if lo >= k:
            return math.inf
        if hi >= k:
            return math.inf if pos > lo else float(lats[lo])
        return float(lats[lo] + (pos - lo) * (lats[hi] - lats[lo]))

    @property
    def tokens_per_s(self) -> float:
        """Throughput over tokens actually emitted — counting
        ``max_new_tokens`` overstated it whenever a request was unfinished
        or retired early (e.g. at admission for single-token requests)."""
        toks = sum(len(r.tokens_out or ()) for r in self.requests)
        return toks / self.modeled_time_s if self.modeled_time_s else 0.0


@dataclasses.dataclass
class ArrivalPredictor:
    """Per-tenant inter-arrival EWMA (ROADMAP "Arrival prediction").

    The scheduler's stagger/WAIT branch needs ``next_arrival_t`` — on a
    replayed trace the engine simply peeks at the trace, but live traffic
    has no oracle. This estimator observes each tenant's admissions and
    predicts the earliest next arrival across tenants:

      * ``observe(tenant, t)`` folds the new inter-arrival gap into the
        tenant's EWMA (``alpha`` weights the newest gap). Observations
        need NOT be globally monotone: with N per-device admission queues
        and a real clock, a pair of arrivals is routinely observed out of
        order — the ABSOLUTE gap |t - last| is folded either way (it is
        the same inter-arrival sample, seen from the other side), and
        ``last`` tracks the max observed time. Dropping out-of-order
        samples (the old behavior) silently starved the EWMA stale;
      * ``predict(now)`` returns min over tenants of the expected next
        arrival — ``last + gap`` while that is still in the future, else
        ``now + gap`` (restart the clock: for a memoryless/Poisson flow
        the expected residual wait is one mean gap regardless of how
        overdue the arrival is). ``inf`` until at least one gap has been
        seen, which leaves the scheduler's never-wait behavior untouched.
    """

    alpha: float = 0.2
    _last: Dict[str, float] = dataclasses.field(default_factory=dict)
    _gap: Dict[str, float] = dataclasses.field(default_factory=dict)

    def observe(self, tenant: str, t: float) -> None:
        last = self._last.get(tenant)
        if last is not None:
            # |t - last| folds out-of-order observations too (normal with
            # per-device queues + a real clock): the reordered pair's gap
            # is the same inter-arrival sample either way round — the old
            # ``t >= last`` guard dropped it and let the EWMA go stale
            gap = abs(t - last)
            prev = self._gap.get(tenant)
            self._gap[tenant] = gap if prev is None else \
                self.alpha * gap + (1.0 - self.alpha) * prev
        self._last[tenant] = max(t, last) if last is not None else t

    def reset(self) -> None:
        """Forget all state. The engine calls this when a run's virtual
        clock restarts at 0 — otherwise a reused engine's stored last-
        arrival times (from the previous trace's end) sit AHEAD of every
        new arrival, ``observe`` drops every gap, and the scheduler is fed
        stagger hints from a dead workload forever."""
        self._last.clear()
        self._gap.clear()

    def gap(self, tenant: str) -> float:
        """The tenant's current EWMA inter-arrival gap (inf if unseen)."""
        return self._gap.get(tenant, math.inf)

    def predict(self, now: float) -> float:
        est = math.inf
        for tenant, gap in self._gap.items():
            t_hat = self._last[tenant] + gap
            if t_hat <= now:
                t_hat = now + gap
            est = min(est, t_hat)
        return est


@dataclasses.dataclass
class _LoopState:
    """Mutable state of one event-loop epoch — a ``run`` replay or an open
    ``serve_forever`` door session. Everything the per-device pass touches
    is factored here so both loops drive the IDENTICAL machinery; only the
    outer termination policy differs (replay terminates on exhaustion, the
    daemon idle-waits while the door is open and flushes on close)."""
    rng: Any
    sessions: List[Any]
    trace: Optional[ScheduleTrace]
    cert: Optional[ScheduleCertifier]
    stream_ids: Dict[str, int]
    id2name: Dict[int, str]
    tenant_dev: Dict[str, int]
    queues: List[List[ServeRequest]]     # per-device admission queues
    pis: List[int]
    waiting: List[List[ServeRequest]]
    inflight: Dict[str, Any]
    now: List[float]                     # per-device virtual clocks
    busy: List[float]                    # analytic charges per device
    committed: List[float]               # admission-committed horizon
    certified: int = 0                   # dispatch records already certified
    n_done: int = 0
    total: int = 0
    oracle: bool = True        # replay: trace lookahead feeds next-arrival
    next_hint: Optional[Any] = None      # daemon: door's scheduled lookahead


class ServingEngine:
    def __init__(self, tenants: Sequence[Tenant], mode: str = "vliw",
                 cost: Optional[CostModel] = None, max_group: int = 16,
                 sched_cfg: SchedulerConfig = SchedulerConfig(),
                 plan_capacity: int = 128, declared_prefill: bool = True,
                 prefill_declare_min: int = 16,
                 predict_arrivals: bool = False,
                 arrival_alpha: float = 0.2,
                 weight_budget_bytes: Optional[int] = 1 << 30,
                 stacked_layers: bool = True,
                 certify: bool = False,
                 num_devices: int = 1,
                 devices: Optional[DeviceSet] = None,
                 live_tune: bool = False,
                 tune_objective: str = "collaborative",
                 admission_control: bool = False,
                 admission: Optional[AdmissionController] = None,
                 token_sink: Optional[Any] = None):
        assert mode in ("time", "batched", "vliw")
        self.tenants = {t.name: t for t in tenants}
        self.mode = mode
        # certify=True records a ScheduleTrace on the vliw session and runs
        # the incremental hazard certifier (repro.analysis.certify) on every
        # tick's dispatches plus whole-run conservation — a HazardViolation
        # raises at the offending dispatch. Off by default: tracing every
        # op record is pure overhead when nobody is checking. The last run's
        # trace stays on ``last_trace`` (mutation tests re-certify it).
        self.certify = certify
        self.last_trace = None
        # stacked_layers=True (default) compiles tenants to layer-stacked
        # templates (one scanned body per homogeneous sub-stack; build and
        # trace size O(1) in depth). False keeps per-layer emission — the
        # bit-identity oracle. The analytic charges below (_ops_time etc.)
        # are regime-independent: the stacked cost model charges a stacked
        # op as L sequential tile-waves, the same total the per-layer path
        # accumulates stage by stage.
        self.stacked_layers = stacked_layers
        # vliw mode compiles dense tenants' prompt passes to KernelPrograms
        # (prefill GEMMs enter the live op pool and coalesce across
        # tenants); declared_prefill=False keeps the analytic serialized
        # charge instead — the ablation baseline the prefill benchmark
        # measures against. Baseline modes always charge analytically:
        # that asymmetry IS the experiment.
        self.declared_prefill = declared_prefill
        # prompts shorter than this stay on the analytic charge even in
        # vliw mode: their GEMMs sit in the same GEMV regime as a decode
        # step (nothing tall to overlap) while a declared program still
        # pays a per-stage dispatch on every layer — measurably worse on
        # staggered short-prompt traces. 16 = the first prefill bucket
        # above the m<=8 GEMV boundary.
        self.prefill_declare_min = prefill_declare_min
        # predict_arrivals=True blinds the scheduler's stagger lookahead to
        # the replay trace and feeds it the per-tenant inter-arrival EWMA
        # instead — the non-replayed-traffic mode. Default (False) keeps
        # the trace-driven oracle. The replay mechanics (when requests
        # BECOME due) always follow the trace; only the scheduler's
        # next-arrival hint changes.
        self.predict_arrivals = predict_arrivals
        self._arrival_pred = ArrivalPredictor(alpha=arrival_alpha)
        # the front door's admit/degrade/shed policy (serving/admission.py):
        # consulted once per request, when it becomes due in the event loop
        # — both under serve_forever (the daemon) and under run (open-loop
        # replay benches). None = admit everything (exact legacy behavior,
        # and the bench's ablation baseline).
        self.admission = admission if admission is not None else (
            AdmissionController() if admission_control else None)
        assert self.admission is None or mode == "vliw", \
            "admission control lives in the vliw event loop"
        # token streaming: called as token_sink(req, token, t) for every
        # token the moment it retires on the modeled clock — the daemon
        # wires the FrontDoor's per-request Ticket delivery here
        self.token_sink = token_sink
        self.cost = cost or CostModel(TPUV5E)
        # the modeled mesh: N virtual device timelines, each with its own
        # scheduler/coalescer (ops never coalesce across devices) sharing
        # one VLIWJit's plan + weight caches (keyed with the device id).
        # Tenants bind to a home device at FIRST admission (placement.py).
        if devices is not None:
            self.devices = devices
            if cost is not None and cost.device is devices.devices[0]:
                devices.bind_cost(0, cost)
            self.cost = devices.cost(0)
        else:
            self.devices = DeviceSet.homogeneous(self.cost.device,
                                                 max(1, int(num_devices)))
            # mesh slot 0 IS the engine's cost model: downstream memos
            # (the template GEMM-suffix table) key on cost identity
            self.devices.bind_cost(0, self.cost)
        assert len(self.devices) == 1 or mode == "vliw", \
            "multi-device serving requires mode='vliw' (baseline modes " \
            "define single-device round semantics)"
        self.placement = PlacementPolicy(self.devices)
        # per-device timeline/busy vectors of the last vliw run (ServeReport
        # device_time_s / device_busy_s)
        self._last_device_time: Optional[List[float]] = None
        self._last_device_busy: Optional[List[float]] = None
        # plan_capacity bounds the JIT's persistent plan caches (program
        # templates + block plans); 0 = rebuild per step (baseline).
        # weight_budget_bytes bounds the dispatch executor's packed-weight
        # cache in BYTES — entries are full padded operand copies, and the
        # stacked per-expert packs of MoE tenants are the big ones
        # live_tune=True puts the collaborative autotuner on the dispatch
        # hot path (core/autotuner.LiveTuner): every coalesced group's
        # (bm, bn, bk) is tuned for the group's actual co-resident shapes
        # and flows into the dispatched superkernels, cached per signature
        # in the JIT's tune cache. tune_objective="greedy" is the Table 1
        # ablation (isolated-latency tiles imposed on the shared device).
        self.jit = VLIWJit(self.cost, sched_cfg=sched_cfg,
                           max_group=max_group, plan_capacity=plan_capacity,
                           weight_budget_bytes=weight_budget_bytes,
                           live_tune=live_tune,
                           tune_objective=tune_objective)
        self.jit_stats = JitStats()
        for t in tenants:
            t.cache = t.model.init_cache(t.max_batch, t.cache_len)
            t.slot_req = [None] * t.max_batch
            t.slot_tok = jnp.zeros((t.max_batch, 1), jnp.int32)
            t.slot_remaining = [0] * t.max_batch

    # ------------------------------------------------------------------
    # modeled step times
    # ------------------------------------------------------------------
    def _ops_time(self, cfg: ModelConfig, m: int) -> float:
        """Serial modeled time for one full decode step at batch m."""
        t = 0.0
        for tag, shape in gemm_population(cfg, m):
            reps = 1 if tag == "unembed" else cfg.num_layers
            t += reps * self.cost.gemm_time(shape)
        return t + self._attn_time(cfg, m)

    def _attn_time(self, cfg: ModelConfig, m: int) -> float:
        """KV-cache streaming time (memory-bound), same for every mode."""
        if cfg.is_attention_free:
            return 0.0
        hd = cfg.resolved_head_dim
        # mean filled length ~ half the cache
        mean_len = 0.5 * max(t.cache_len for t in self.tenants.values()
                             if t.cfg is cfg) if any(
            t.cfg is cfg for t in self.tenants.values()) else 64
        bytes_ = 2 * cfg.num_layers * cfg.num_kv_heads * mean_len * hd * 2 * m
        return bytes_ / self.cost.device.hbm_bw

    def _prefill_attn_time(self, cfg: ModelConfig, prompt_len: int) -> float:
        """KV write-back + causal attention streaming for one prompt
        (memory-bound, the same accounting family as ``_attn_time``): the S
        new K/V entries are written once and each query position streams
        the prefix behind it (~S(S+1)/2 entries). Charged at prefill
        completion on the declared path and folded into ``_prefill_time``
        for the analytic one, so both paths model the same traffic."""
        if cfg.is_attention_free:
            return 0.0
        hd = cfg.resolved_head_dim
        s = prompt_len
        per_entry = 2 * cfg.num_layers * cfg.num_kv_heads * hd * 2
        return per_entry * (s + s * (s + 1) / 2.0) / self.cost.device.hbm_bw

    def _prefill_time(self, cfg: ModelConfig, prompt_len: int) -> float:
        """Analytic serialized prompt cost: GEMMs + KV/attention traffic
        (the latter used to be dropped, making prefill inconsistently
        cheaper than ``_attn_time``-style decode accounting)."""
        t = 0.0
        for tag, shape in gemm_population(cfg, prompt_len):
            reps = 1 if tag == "unembed" else cfg.num_layers
            t += reps * self.cost.gemm_time(shape)
        return t + self._prefill_attn_time(cfg, prompt_len)

    def _request_cost_s(self, t: Tenant, req: ServeRequest) -> float:
        """Modeled end-to-end service cost of one request — the front
        door's admission currency: full prefill plus the remaining decode
        steps at the tenant's batch width (amortized: a decode step is
        shared by up to ``max_batch`` requests, so the marginal per-token
        cost is the batched step divided by the batch)."""
        m = max(t.max_batch, 1)
        per_tok = self._ops_time(t.cfg, m) / m
        return self._prefill_time(t.cfg, req.prompt_len) \
            + max(req.max_new_tokens - 1, 0) * per_tok

    def _emit_token(self, req: ServeRequest, tok: int, t: float) -> None:
        if self.token_sink is not None:
            self.token_sink(req, tok, t)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _make_prompt(self, tenant: Tenant, req: ServeRequest,
                     rng: jax.Array) -> jax.Array:
        """The request's synthetic prompt [1, prompt_len] — derived from
        (rng, req_id) only, so every mode and prefill path sees the exact
        same tokens."""
        return jax.random.randint(jax.random.fold_in(rng, req.req_id),
                                  (1, req.prompt_len), 0,
                                  tenant.cfg.vocab_size)

    def _admit(self, tenant: Tenant, req: ServeRequest, rng: jax.Array,
               now: float) -> float:
        """Prefill ``req`` into the tenant. Returns the modeled prefill time
        (0.0 with ``tokens_out`` still None means: no free slot, retry).

        A request whose prefill already produced its only token
        (``max_new_tokens <= 1``) is retired here, at admission, in every
        mode: it never occupies a decode slot, so it cannot join a decode
        step it does not need (which used to inflate its latency by one
        step and emit an extra token). ``finish_t`` is set for the caller
        to count it as done."""
        needs_slot = req.max_new_tokens > 1
        slots = [i for i, r in enumerate(tenant.slot_req) if r is None]
        if needs_slot and not slots:
            return 0.0  # caller retries later
        m = tenant.model
        pbatch = {"tokens": self._make_prompt(tenant, req, rng)}
        if m.cfg.arch_type == "vlm":
            pbatch["patch_embeds"] = jnp.zeros(
                (1, m.cfg.num_patch_tokens, m.cfg.d_model), m.dtype)
        if m.cfg.is_encdec:
            pbatch["frames"] = jnp.zeros(
                (1, m.cfg.encoder_seq_len, m.cfg.d_model), m.dtype)
        logits, pc = m.prefill(tenant.params, pbatch,
                               cache_len=tenant.cache_len)
        tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        req.tokens_out = [int(tok)]
        dt = self._prefill_time(m.cfg, req.prompt_len)
        self._emit_token(req, int(tok), now + dt)
        if not needs_slot:
            req.finish_t = now + dt    # done at admission: no decode steps
            return dt
        # write row into the tenant's slotted cache
        slot = slots[0]
        new_layers = {}
        for key, arr in tenant.cache["layers"].items():
            new_layers[key] = arr.at[:, slot].set(pc["layers"][key][:, 0])
        tenant.cache = {
            "pos": tenant.cache["pos"].at[slot].set(pc["pos"][0]),
            "layers": new_layers,
        }
        tenant.slot_tok = tenant.slot_tok.at[slot, 0].set(tok)
        tenant.slot_req[slot] = req
        tenant.slot_remaining[slot] = req.max_new_tokens - 1
        return dt

    # ------------------------------------------------------------------
    # one decode round (baseline modes only)
    # ------------------------------------------------------------------
    def _decode_round(self, now: float = 0.0) -> float:
        live = [t for t in self.tenants.values() if t.active_slots()]
        dt = 0.0
        if self.mode == "batched":
            for t in live:
                dt += self._tenant_batched_step(t, now + dt)
        else:  # time: every active request decodes alone, serialized
            for t in live:
                n_active = len(t.active_slots())
                logits, t.cache = t.model.decode_step(t.params, t.slot_tok,
                                                      t.cache)
                self._consume(t, logits, now + dt)
                dt += n_active * self._ops_time(t.cfg, 1)
        return dt

    def _tenant_batched_step(self, t: Tenant, now: float = 0.0) -> float:
        logits, t.cache = t.model.decode_step(t.params, t.slot_tok, t.cache)
        dt = self._ops_time(t.cfg, len(t.active_slots()))
        self._consume(t, logits, now + dt)
        return dt

    def _consume(self, t: Tenant, logits: jax.Array, now: float = 0.0
                 ) -> None:
        toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        t.slot_tok = toks[:, None]
        for slot in t.active_slots():
            req = t.slot_req[slot]
            req.tokens_out.append(int(toks[slot]))
            self._emit_token(req, int(toks[slot]), now)
            t.slot_remaining[slot] -= 1

    def _retire(self, t: Tenant, now: float) -> List[ServeRequest]:
        """Free slots of finished requests; returns the retired requests
        (the vliw trace records their ids, everyone else just counts)."""
        done: List[ServeRequest] = []
        for slot in t.active_slots():
            if t.slot_remaining[slot] <= 0:
                req = t.slot_req[slot]
                req.finish_t = now
                t.slot_req[slot] = None
                done.append(req)
        return done

    # ------------------------------------------------------------------
    # the event loop (vliw mode)
    # ------------------------------------------------------------------
    def _jit_capable(self, t: Tenant) -> bool:
        # layerwise kernel programs cover dense/vlm GQA decode, MoE decode
        # (router glue + per-expert GemmStages) and SSM decode (selective-
        # scan glue) over bf16/f32 caches; int8-KV tenants (and hybrid /
        # encdec archs) take the monolithic batched step — see the
        # arch-support matrix in the module docstring
        return t.cfg.arch_type in ("dense", "vlm", "moe", "ssm") \
            and not getattr(t.model, "kv_quant", False)

    def _prefill_capable(self, t: Tenant) -> bool:
        # declared prefill covers pure-dense tenants (a vlm prompt needs
        # the patch-embed projector; it keeps the analytic charge)
        return self.declared_prefill and t.cfg.arch_type == "dense" \
            and self._jit_capable(t)

    def _declare_prefill(self, t: Tenant, req: ServeRequest, rng: jax.Array,
                         stream_id: int, now: float
                         ) -> Optional[KernelProgram]:
        """Compile+bind ``req``'s prompt pass as a prefill KernelProgram.

        Returns None when the tenant has no free decode slot (the caller
        keeps the request waiting). The slot is RESERVED here — legal
        because the tenant admits nothing else and builds no decode program
        while this program is inflight — but its token/cache state lands at
        the completion event (``_on_prefill_complete``), not now: the
        device hasn't executed anything yet on the virtual clock.

        The program's deadline discounts the decode steps still to come
        (mirroring ``_build_program``) so a long prompt inherits its
        request's end-to-end urgency for EDF anchoring and the stagger
        budget."""
        needs_slot = req.max_new_tokens > 1
        slots = [i for i, r in enumerate(t.slot_req) if r is None]
        if needs_slot and not slots:
            return None
        s = req.prompt_len
        assert s <= t.cache_len, (s, t.cache_len)
        bucket = prefill_bucket(s)
        prompt = self._make_prompt(t, req, rng)
        padded = jnp.pad(prompt, ((0, 0), (0, bucket - s)))
        template = self.jit.plan_cache.get_or_build(
            prefill_program_cache_key(t.model, t.params, bucket, t.cache,
                                      stacked=self.stacked_layers),
            lambda: build_dense_prefill_template(
                t.model, t.params, bucket, stacked=self.stacked_layers),
            guard=(t.model, t.params),
            group=("tenant-prefill", t.name, bucket))
        final = req.arrival_t + req.slo_s
        n_active = len(t.active_slots()) + (1 if needs_slot else 0)
        step_t = self._ops_time(t.cfg, max(n_active, 1))
        deadline = final - max(req.max_new_tokens - 1, 0) * step_t
        if deadline <= now:
            deadline = final
        slot = slots[0] if needs_slot else None
        prog = template.bind(
            stream_id=stream_id, tokens=padded, cache=t.cache,
            arrival_t=now, deadline_t=deadline,
            req_deadlines=((req.req_id, final),),
            # the prefill epilogue writes exactly its reserved slot's rows
            kv_writes=(("kv", t.name, slot),) if slot is not None else (),
            env_extra={"real_len": s, "slot": slot, "req": req})
        if needs_slot:
            t.slot_req[slot] = req
            t.slot_remaining[slot] = req.max_new_tokens - 1
        return prog

    def _on_prefill_complete(self, t: Tenant, prog: KernelProgram,
                             now: float) -> Tuple[float, int]:
        """Land a completed prefill: first token, KV slot state, traffic
        charge. Returns (now, requests retired here)."""
        req: ServeRequest = prog.env["req"]
        tok = jnp.argmax(prog.env["logits"][0]).astype(jnp.int32)
        req.tokens_out = [int(tok)]
        now += self._prefill_attn_time(t.cfg, prog.env["real_len"])
        self._emit_token(req, int(tok), now)
        slot = prog.env["slot"]
        if slot is None:
            req.finish_t = now     # single token: done at prefill, no slot
            return now, 1
        t.cache = prog.env["cache"]
        t.slot_tok = t.slot_tok.at[slot, 0].set(tok)
        return now, 0

    def _build_program(self, t: Tenant, stream_id: int, now: float
                       ) -> KernelProgram:
        """Bind the tenant's next decode step, carrying the tightest
        *this-step* deadline of its batch into the program.

        Steady-state hot path: the compiled ``ProgramTemplate`` (stage
        list + glue closures + weight keys) comes from the JIT's persistent
        plan cache keyed by (model identity, batch m, dtype, cache
        geometry) and identity-guarded on ``(t.model, t.params)`` — only the per-step
        env (tokens, KV cache refs, deadlines) is rebuilt per tick, so the
        cache misses only on the first step, a batch-size change, or a
        weight hot-swap.

        A request's final deadline is discounted by the modeled time of its
        decode steps still to come AFTER this one, so the scheduler's slack
        (and therefore its WAIT budget) reflects whole-request progress,
        not just the current step's GEMM suffix — otherwise a request with
        zero end-to-end slack would look staggerable at every step.

        Already-missed requests are ignored while a healthy batchmate
        exists — one hopeless straggler must not demote the whole tenant's
        programs from EDF anchoring and cascade misses onto requests that
        still have slack. Only when every batched request has missed does
        the program carry the raw (past) final deadline; that value is
        step-invariant, which the scheduler's per-(stream, deadline)
        eviction dedup relies on."""
        reqs = [(t.slot_req[s], t.slot_remaining[s])
                for s in t.active_slots()]
        # one full decode step (GEMMs + KV streaming; _ops_time includes
        # _attn_time already) at the ACTIVE batch size — charging max_batch
        # over-discounted partially-filled tenants' remaining-step
        # deadlines, artificially shrinking their WAIT slack
        step_t = self._ops_time(t.cfg, max(len(reqs), 1))
        finals = [r.arrival_t + r.slo_s for r, _ in reqs]
        step_deadlines = [f - max(rem - 1, 0) * step_t
                          for f, (_, rem) in zip(finals, reqs)]
        future = [d for d in step_deadlines if d > now]
        deadline = min(future) if future else \
            min(finals) if finals else math.inf
        batch = int(t.slot_tok.shape[0])
        arch = t.cfg.arch_type
        stacked = self.stacked_layers
        if arch == "moe":
            key = moe_program_cache_key(t.model, t.params, batch, t.cache,
                                        stacked=stacked)
            build = lambda: build_moe_decode_template(  # noqa: E731
                t.model, t.params, batch, stacked=stacked)
        elif arch == "ssm":
            key = ssm_program_cache_key(t.model, t.params, batch, t.cache,
                                        stacked=stacked)
            build = lambda: build_ssm_decode_template(  # noqa: E731
                t.model, t.params, batch, stacked=stacked)
        else:
            key = dense_program_cache_key(t.model, t.params, batch, t.cache,
                                          stacked=stacked)
            build = lambda: build_dense_decode_template(  # noqa: E731
                t.model, t.params, batch, stacked=stacked)
        template = self.jit.plan_cache.get_or_build(
            key, build, guard=(t.model, t.params), group=("tenant", t.name))
        return template.bind(
            stream_id=stream_id, tokens=t.slot_tok, cache=t.cache,
            arrival_t=now, deadline_t=deadline,
            # a decode step appends one position to every batch row of the
            # tenant's slotted cache (idle rows advance too)
            kv_writes=tuple(("kv", t.name, s) for s in range(batch)),
            req_deadlines=tuple((r.req_id, f)
                                for (r, _), f in zip(reqs, finals)))

    def _open_loop(self, rng: jax.Array, *, oracle: bool = True,
                   next_hint: Optional[Any] = None) -> _LoopState:
        # each epoch is a fresh virtual-clock epoch: arrival history from a
        # previous trace describes a different workload (and would poison
        # observe(), whose last-arrival times now sit past every new t)
        self._arrival_pred.reset()
        n_dev = len(self.devices)
        # one JitSession PER DEVICE — each owns its scheduler, coalescer,
        # virtual free instant and EDF anchor set — all sharing one
        # VLIWJit's plan/block/weight caches (device-id-keyed) and ONE
        # ScheduleTrace, so the certifier sees the whole mesh. Device 0
        # reuses the jit's own coalescer (exact single-device behavior).
        trace = ScheduleTrace() if self.certify else None
        sessions = [self.jit.session(
            device=d, cost=None if d == 0 else self.devices.cost(d),
            trace=trace) for d in range(n_dev)]
        stream_ids = {name: i for i, name in enumerate(self.tenants)}
        return _LoopState(
            rng=rng, sessions=sessions, trace=trace,
            cert=ScheduleCertifier() if trace is not None else None,
            stream_ids=stream_ids,
            id2name={i: name for name, i in stream_ids.items()},
            tenant_dev={n: p.device
                        for n, p in self.placement.assignments.items()},
            queues=[[] for _ in range(n_dev)], pis=[0] * n_dev,
            waiting=[[] for _ in range(n_dev)], inflight={},
            now=[0.0] * n_dev, busy=[0.0] * n_dev,
            committed=[0.0] * n_dev, oracle=oracle, next_hint=next_hint)

    def _dev_of(self, st: _LoopState, name: str) -> int:
        # placement binds ONCE, at the tenant's first admission; an
        # expert-parallel MoE tenant spanning the mesh registers its
        # span with its home session, which prices the all-to-all
        # into every expert GEMM's slack and plan estimate
        d = st.tenant_dev.get(name)
        if d is None:
            t = self.tenants[name]
            pl = self.placement.place(name, t.cfg, batch=t.max_batch)
            d = st.tenant_dev[name] = pl.device
            if pl.expert_span > 1:
                st.sessions[d].set_stream_span(st.stream_ids[name],
                                               pl.expert_span)
        return d

    def _route(self, st: _LoopState, req: ServeRequest) -> int:
        """Append ``req`` to its home device's admission queue."""
        d = self._dev_of(st, req.tenant)
        st.queues[d].append(req)
        st.total += 1
        return d

    def _door_decision(self, st: _LoopState, req: ServeRequest, d: int
                       ) -> bool:
        """Consult the admission controller for one due request (fires
        exactly once, when the request first becomes due on its device's
        clock). Returns False when the request was shed at the door — it
        never occupies a slot and stays out of the schedule trace, like a
        refused admission, but counts as an SLO miss in the report."""
        t = self.tenants[req.tenant]
        cost_s = self._request_cost_s(t, req)
        backlog = max(0.0, st.committed[d] - st.now[d])
        dec = self.admission.decide(req, st.now[d], backlog, cost_s,
                                    self._arrival_pred.gap(req.tenant))
        if dec.action == "shed":
            req.shed = True
            st.n_done += 1
            return False
        if dec.action == "degrade":
            req.degraded_from = req.tier
            req.tier = dec.tier
            req.slo_s = dec.slo_s
        # commit the modeled cost to the device's completion horizon —
        # the backlog meter later decisions are judged against
        st.committed[d] = max(st.committed[d], st.now[d]) + cost_s
        return True

    def _device_pass(self, st: _LoopState, d: int) -> bool:
        """One pass over device ``d``'s timeline: drain due arrivals
        (through the admission controller when the front door is on),
        admit waiting requests, keep JIT-capable tenants' programs in the
        pool, take one scheduler decision, land completions, and step
        non-JIT tenants. Returns True if anything progressed."""
        progressed = False
        session, q, wq = st.sessions[d], st.queues[d], st.waiting[d]
        trace, cert, rng = st.trace, st.cert, st.rng
        now, busy = st.now, st.busy
        # 1. live admission on device d's timeline. Dense tenants
        #    DECLARE the prompt pass as a prefill KernelProgram —
        #    its GEMMs join the device's live op pool and coalesce
        #    with decode (and other tenants' prefill) traffic; the
        #    tenant's decode joins only after its completion event.
        #    Non-dense tenants keep the analytic serialized charge.
        #    A tenant with a program inflight (or full slots)
        #    admits at its next step boundary, but other tenants'
        #    due requests are admitted past it, not blocked.
        while st.pis[d] < len(q) and q[st.pis[d]].arrival_t <= now[d]:
            req = q[st.pis[d]]
            st.pis[d] += 1
            if self.predict_arrivals or self.admission is not None:
                self._arrival_pred.observe(req.tenant, req.arrival_t)
            if self.admission is not None \
                    and not self._door_decision(st, req, d):
                progressed = True   # shed at the door: resolved right here
                continue
            wq.append(req)
        still: List[ServeRequest] = []
        for req in wq:
            t = self.tenants[req.tenant]
            if req.tenant in st.inflight:
                still.append(req)
                continue
            if self._prefill_capable(t) \
                    and req.prompt_len >= self.prefill_declare_min:
                prog = self._declare_prefill(
                    t, req, rng, st.stream_ids[req.tenant], now[d])
                if prog is None:
                    still.append(req)  # slots full; retry later
                    continue
                st.inflight[req.tenant] = prog
                session.admit(prog)
                if trace is not None:
                    trace.req_admits.append((req.req_id, now[d]))
                    trace.req_devices[req.req_id] = d
                progressed = True
                continue
            dt = self._admit(t, req, rng, now[d])
            if dt == 0.0 and req.tokens_out is None:
                still.append(req)  # tenant slots full; retry later
                continue
            now[d] += dt
            busy[d] += dt
            if trace is not None:
                trace.req_admits.append((req.req_id, now[d]))
                trace.req_devices[req.req_id] = d
            if not math.isnan(req.finish_t):
                st.n_done += 1     # retired at admission (single token)
                if trace is not None:
                    trace.req_retires.append((req.req_id, now[d]))
                    trace.retire_devices[req.req_id] = d
            progressed = True
        st.waiting[d] = still
        if self.predict_arrivals:
            hint = self._arrival_pred.predict(now[d])
        else:
            # replay: oracle lookahead into the routed trace; the daemon
            # additionally consults the door's scheduled submissions
            hint = q[st.pis[d]].arrival_t if st.pis[d] < len(q) \
                else math.inf
            if not st.oracle and st.next_hint is not None:
                nxt = st.next_hint(now[d])
                if nxt is not None:
                    hint = min(hint, nxt)
        session.set_next_arrival(hint)

        # 2. every JIT-capable tenant homed here with live requests
        #    keeps a program in this device's pool — admitted
        #    between dispatches, not per round
        for name, t in self.tenants.items():
            if st.tenant_dev.get(name) != d:
                continue
            if self._jit_capable(t) and name not in st.inflight \
                    and t.active_slots():
                prog = self._build_program(t, st.stream_ids[name],
                                           now[d])
                if t.cfg.arch_type in ("moe", "ssm"):
                    session.stats.nondense_programs += 1
                st.inflight[name] = prog
                session.admit(prog)
                progressed = True

        # 3. one scheduler decision on device d's virtual clock
        ev = session.tick(now[d])
        if cert is not None:
            # certify this tick's new dispatches at the tick they
            # happened — a HazardViolation raises right here, with
            # the offending group as the last trace record. The
            # trace is shared, so records from every device flow
            # through the same certifier (placement checks included)
            for dr in trace.dispatches[st.certified:]:
                cert.observe(dr)
            st.certified = len(trace.dispatches)
        progressed |= ev.kind != "idle"
        now[d] = max(now[d], ev.t)
        for prog in ev.completed:
            t = self.tenants[st.id2name[prog.stream_id]]
            del st.inflight[st.id2name[prog.stream_id]]
            if prog.kind == "prefill":
                t0 = now[d]
                now[d], done = self._on_prefill_complete(
                    t, prog, now[d])
                busy[d] += now[d] - t0
                st.n_done += done
                if done and trace is not None:
                    trace.req_retires.append(
                        (prog.env["req"].req_id, now[d]))
                    trace.retire_devices[prog.env["req"].req_id] = d
                continue
            t.cache = prog.env["cache"]
            # KV streaming charged at the ACTIVE batch size: idle
            # slots have no cache rows to read, so charging
            # max_batch over-billed partially-filled tenants
            attn = self._attn_time(t.cfg,
                                   max(len(t.active_slots()), 1))
            self._consume(t, prog.env["logits"][:, None, :],
                          now[d] + attn)
            now[d] += attn
            busy[d] += attn
            retired = self._retire(t, now[d])
            st.n_done += len(retired)
            if trace is not None:
                trace.req_retires.extend(
                    (r.req_id, now[d]) for r in retired)
                for r in retired:
                    trace.retire_devices[r.req_id] = d

        # 4. non-JIT tenants homed here interleave monolithic
        #    batched steps on this device's clock
        for name, t in self.tenants.items():
            if st.tenant_dev.get(name) != d:
                continue
            if not self._jit_capable(t) and t.active_slots():
                dt = self._tenant_batched_step(t, now[d])
                now[d] += dt
                busy[d] += dt
                retired = self._retire(t, now[d])
                st.n_done += len(retired)
                if trace is not None:
                    trace.req_retires.extend(
                        (r.req_id, now[d]) for r in retired)
                    for r in retired:
                        trace.retire_devices[r.req_id] = d
                progressed = True
        return progressed

    def _close_loop(self, st: _LoopState,
                    requests: Sequence[ServeRequest]) -> None:
        trace, cert, sessions = st.trace, st.cert, st.sessions
        if trace is not None:
            # close the request lifecycle, then balance it: SLO-demoted
            # requests from every device's scheduler, plus admitted
            # requests that never finished (refused-admission and
            # door-shed requests were never admitted, so they stay out
            # of the trace entirely)
            trace.evicted = set()
            for s in sessions:
                trace.evicted |= set(s.sched.demoted_requests())
            by_id = {r.req_id: r for r in requests}
            admitted = {rid for rid, _ in trace.req_admits}
            trace.unfinished = {rid for rid in admitted
                                if math.isnan(by_id[rid].finish_t)}
            cert.checks += 1
            cert.violations.extend(check_conservation(trace))
            sessions[0].stats.hazard_checks += cert.checks
            sessions[0].stats.hazard_violations += len(cert.violations)
        self.last_trace = trace
        # per-device dispatch time lives in each session's stats; analytic
        # charges (prefill/attention/batched steps) were accumulated above
        self._last_device_time = list(st.now)
        self._last_device_busy = [
            st.busy[d] + sessions[d].stats.modeled_time_s
            for d in range(len(sessions))]
        for s in sessions:
            self.jit_stats.merge(s.stats)

    def _run_event_loop(self, pending: List[ServeRequest], rng: jax.Array
                        ) -> float:
        st = self._open_loop(rng)
        # route the arrival-sorted trace onto per-device admission queues;
        # _dev_of fires in arrival order of each tenant's FIRST request —
        # the same binding a lazy per-admission call would make, but the
        # queues keep one slow device's backlog from head-of-line-blocking
        # another device's due requests
        for req in pending:
            self._route(st, req)
        n_dev = len(self.devices)
        while True:
            progressed = False
            for d in range(n_dev):
                progressed |= self._device_pass(st, d)
            if st.n_done >= st.total \
                    and not any(s.live for s in st.sessions) \
                    and all(st.pis[d] >= len(st.queues[d])
                            for d in range(n_dev)) \
                    and not any(st.waiting):
                break
            if not progressed:
                advanced = False
                for d in range(n_dev):
                    # idle device: its clock jumps to its next arrival
                    if st.pis[d] < len(st.queues[d]) \
                            and st.now[d] < st.queues[d][st.pis[d]].arrival_t:
                        st.now[d] = st.queues[d][st.pis[d]].arrival_t
                        advanced = True
                if advanced:
                    continue
                if not any(st.waiting):
                    break
                # stall guard: every queue is exhausted, every waiting
                # request was refused admission, and there is nothing
                # inflight or decoding anywhere whose completion could
                # change that — another iteration would see the identical
                # state, so the loop must terminate (the requests stay
                # unfinished and surface in ServeReport.unfinished)
                if not any(s.live for s in st.sessions) \
                        and not st.inflight \
                        and not any(t.active_slots()
                                    for t in self.tenants.values()):
                    break
        self._close_loop(st, pending)
        return max(st.now)

    # ------------------------------------------------------------------
    # the front door (daemon mode)
    # ------------------------------------------------------------------
    def _live_stats(self, st: _LoopState, served: List[ServeRequest],
                    t: float) -> Dict[str, Any]:
        return {
            "t": t,
            "submitted": len(served),
            "finished": sum(1 for r in served
                            if not math.isnan(r.finish_t)),
            "shed": sum(1 for r in served if r.shed),
            "inflight": len(st.inflight),
            "waiting": sum(len(w) for w in st.waiting),
            "device_time_s": list(st.now),
        }

    def serve_forever(self, door: FrontDoor, *,
                      clock: Optional[Any] = None,
                      rng: Optional[jax.Array] = None,
                      idle_poll_s: float = 0.005,
                      on_stats: Optional[Any] = None,
                      stats_interval_s: float = 1.0) -> ServeReport:
        """Serve continuously from ``door`` until it closes (daemon mode).

        The same per-device event-loop machinery as ``run``, driven by a
        clock instead of a finite trace: requests stream in through the
        thread-safe ``FrontDoor`` (arrival-stamped on the clock), the
        admission controller (when configured) admits / degrades / sheds
        each one as it becomes due, tokens stream out per request the
        moment they retire (``FrontDoor.deliver`` -> per-request
        ``Ticket``), and the engine IDLES while the door is open and
        empty — the replay stall guard becomes an idle-wait. Closing the
        door flushes all in-flight work, then the loop terminates and
        returns the epoch's ``ServeReport`` (shed requests included, as
        SLO misses).

        ``clock`` is a ``MonotonicClock`` by default — the real wall
        clock; per-device modeled timelines are floored at real elapsed
        time every iteration so arrivals, deadlines and modeled charges
        share one axis. Pass a follower ``VirtualClock`` for
        deterministic tests/benches: it only tracks the modeled
        timelines, so a door pre-loaded with scheduled submissions
        replays with exactly the per-device clock semantics of ``run``.
        ``on_stats`` (optional) is called at most every
        ``stats_interval_s`` clock seconds with a live-stats dict — the
        daemon's heartbeat."""
        assert self.mode == "vliw", \
            "daemon serving is a vliw-engine feature (baseline modes " \
            "define closed-trace round semantics)"
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        clock = clock if clock is not None else MonotonicClock()
        wall0 = _time.perf_counter()
        st = self._open_loop(rng, oracle=False,
                             next_hint=door.next_arrival)
        served: List[ServeRequest] = []
        seen_ids: Dict[int, int] = {}
        n_dev = len(self.devices)
        prev_sink = self.token_sink
        if prev_sink is None:
            self.token_sink = door.deliver
        last_stats = clock.now()
        try:
            while True:
                now_r = clock.now()
                if clock.authoritative:
                    # real clock: a device cannot serve in the past — its
                    # modeled timeline is floored at real elapsed time
                    for d in range(n_dev):
                        st.now[d] = max(st.now[d], now_r)
                for req in door.poll(now_r):
                    if req.req_id in seen_ids:
                        raise ValueError(
                            f"duplicate req_id {req.req_id} through the "
                            f"door — request ids key prompt synthesis "
                            f"and retirement accounting")
                    seen_ids[req.req_id] = 1
                    served.append(req)
                    self._route(st, req)
                progressed = False
                for d in range(n_dev):
                    progressed |= self._device_pass(st, d)
                # a follower clock tracks the modeled timelines; the real
                # clock ignores this (time advances itself)
                clock.advance_to(max(st.now))
                if on_stats is not None \
                        and clock.now() - last_stats >= stats_interval_s:
                    last_stats = clock.now()
                    on_stats(self._live_stats(st, served, last_stats))
                if progressed:
                    continue
                # idle devices jump to their next released-but-not-yet-due
                # arrival (the replay idle-jump, on routed requests)
                advanced = False
                for d in range(n_dev):
                    if st.pis[d] < len(st.queues[d]) \
                            and st.now[d] < st.queues[d][st.pis[d]].arrival_t:
                        st.now[d] = st.queues[d][st.pis[d]].arrival_t
                        advanced = True
                if advanced:
                    continue
                # nothing live anywhere. With the door closed and drained
                # the flush is complete — terminate (waiting requests that
                # can never admit surface in ServeReport.unfinished, the
                # replay stall guard's behavior). With the door OPEN,
                # idle-wait instead of terminating: a new submission or
                # the closing of the door are the only remaining sources
                # of progress.
                if not any(s.live for s in st.sessions) \
                        and not st.inflight \
                        and not any(t.active_slots()
                                    for t in self.tenants.values()):
                    if door.finished(now_r) \
                            and all(st.pis[d] >= len(st.queues[d])
                                    for d in range(n_dev)):
                        break
                    targets = []
                    nxt = door.next_arrival(now_r)
                    if nxt is not None:
                        targets.append(max(nxt, now_r))
                    if door.close_at is not None \
                            and door.close_at > now_r:
                        targets.append(door.close_at)
                    clock.sleep_until(min(targets) if targets
                                      else now_r + idle_poll_s)
        finally:
            self.token_sink = prev_sink
        self._close_loop(st, served)
        makespan = max(st.now) if st.now else 0.0
        wall = _time.perf_counter() - wall0
        return ServeReport("vliw", served, makespan, wall,
                           jit=self.jit_stats,
                           device_time_s=self._last_device_time,
                           device_busy_s=self._last_device_busy)

    # ------------------------------------------------------------------
    # round loop (baseline modes: rounds ARE their semantics)
    # ------------------------------------------------------------------
    def _run_rounds(self, pending: List[ServeRequest], rng: jax.Array
                    ) -> float:
        now, pi, n_done = 0.0, 0, 0
        while n_done < len(pending):
            progressed = False
            while pi < len(pending) and pending[pi].arrival_t <= now:
                req = pending[pi]
                t = self.tenants[req.tenant]
                dt = self._admit(t, req, rng, now)
                if dt == 0.0 and req.tokens_out is None:
                    break  # tenant full; retry after this round
                now += dt
                if not math.isnan(req.finish_t):
                    n_done += 1        # retired at admission (single token)
                pi += 1
                progressed = True
            dt = self._decode_round(now)
            if dt == 0.0 and not progressed:
                if pi < len(pending):
                    now = max(now, pending[pi].arrival_t)
                    continue
                break
            now += dt
            for t in self.tenants.values():
                n_done += len(self._retire(t, now))
        return now

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[ServeRequest],
            rng: Optional[jax.Array] = None) -> ServeReport:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        # request identity keys everything downstream — prompt synthesis
        # (_make_prompt folds req_id into the rng), the scheduler's
        # per-request eviction dedup, and the certifier's conservation
        # check — so a trace with colliding ids must be rejected up front
        # instead of silently double-counting one identity
        ids: Dict[int, int] = {}
        for r in trace:
            ids[r.req_id] = ids.get(r.req_id, 0) + 1
        dupes = sorted(i for i, n in ids.items() if n > 1)
        if dupes:
            raise ValueError(
                f"duplicate req_id(s) in trace: {dupes} — request ids must "
                f"be unique per run (they key prompt synthesis, eviction "
                f"dedup and retirement accounting)")
        # run() serves private COPIES of the requests: results (tokens_out,
        # finish_t, shed, tier degradation) land on the copies in the
        # returned report, and the caller's trace objects are NEVER
        # mutated — a trace can be replayed across engines and modes
        # without the defensive deepcopy every call site used to need
        requests = [dataclasses.replace(
            r, finish_t=float("nan"), tokens_out=None, shed=False,
            degraded_from=None) for r in trace]
        pending = sorted(requests, key=lambda r: r.arrival_t)
        wall0 = _time.perf_counter()
        if self.mode == "vliw":
            makespan = self._run_event_loop(pending, rng)
            dev_t, dev_b = self._last_device_time, self._last_device_busy
        else:
            makespan = self._run_rounds(pending, rng)
            dev_t = dev_b = None
        wall = _time.perf_counter() - wall0
        return ServeReport(self.mode, requests, makespan, wall,
                           jit=self.jit_stats if self.mode == "vliw" else None,
                           device_time_s=dev_t, device_busy_s=dev_b)
