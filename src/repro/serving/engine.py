"""Multi-tenant serving engine: event-driven OoO serving with live admission.

Three execution modes, mirroring the paper's comparison end-to-end:

  * "time"    — each request decodes alone, requests strictly serialized
                (GPU time-multiplexing, §4.1);
  * "batched" — continuous batching *within* each tenant, tenants serialized
                (ModelBatch / TensorRT-style, §4.2's strongest baseline);
  * "vliw"    — OUR engine: a single virtual-time **event loop** over an
                admission-open ``JitSession`` (core/jit.py). Dense tenants'
                decode steps are compiled to KernelPrograms and coalesced
                ACROSS tenants; a request arriving mid-flight is prefilled
                and its tenant's next program joins the live op pool
                *between superkernel dispatches*, not at a round boundary.
                The trace's future arrival times are fed to the OoO
                scheduler, so its stagger/WAIT branch executes for real; the
                tightest per-request deadline of each tenant's batch flows
                into per-op ``latest_start_t`` for EDF anchoring and
                eviction of already-missed stragglers. Non-dense tenants
                fall back to monolithic batched steps inside the same loop.

The baseline modes keep their defining round-synchronous semantics
(``_run_rounds``); greedy tokens are asserted identical across all three
modes because batch rows are independent, so scheduling order cannot change
any request's token stream.

Token generation is REAL (greedy argmax through the actual models); time is
attributed with the calibrated device cost model, since wall-clock on a CPU
host says nothing about TPU latency. Both are reported.

Continuous batching mechanics: each tenant owns a slotted decode cache
(``max_batch`` rows, per-row positions). Admission prefills a request
(real ``Model.prefill``) and writes its KV rows into a free slot; completed
requests free their slot mid-flight — per-row ``pos`` makes mixed-depth
batches correct (models/attention.py).
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.costmodel import CostModel, GemmShape, TPUV5E
from repro.core.jit import (JitStats, KernelProgram, VLIWJit,
                            build_dense_decode_template,
                            dense_program_cache_key)
from repro.core.kernelspec import gemm_population
from repro.core.scheduler import SchedulerConfig
from repro.models.model import Model
from repro.serving.workload import ServeRequest


@dataclasses.dataclass
class Tenant:
    name: str
    model: Model
    params: Any
    cache_len: int = 64
    max_batch: int = 4
    # runtime state
    cache: Any = None
    slot_req: List[Optional[ServeRequest]] = dataclasses.field(
        default_factory=list)
    slot_tok: Any = None
    slot_remaining: List[int] = dataclasses.field(default_factory=list)

    @property
    def cfg(self) -> ModelConfig:
        return self.model.cfg

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]


@dataclasses.dataclass
class ServeReport:
    mode: str
    requests: List[ServeRequest]
    modeled_time_s: float
    wall_time_s: float
    jit: Optional[JitStats] = None

    @property
    def slo_attainment(self) -> float:
        done = [r for r in self.requests if not np.isnan(r.finish_t)]
        return sum(r.met_slo for r in done) / max(len(done), 1)

    @property
    def mean_latency(self) -> float:
        return float(np.mean([r.latency for r in self.requests]))

    def p_latency(self, q: float) -> float:
        return float(np.quantile([r.latency for r in self.requests], q))

    @property
    def tokens_per_s(self) -> float:
        toks = sum(r.max_new_tokens for r in self.requests)
        return toks / self.modeled_time_s if self.modeled_time_s else 0.0


class ServingEngine:
    def __init__(self, tenants: Sequence[Tenant], mode: str = "vliw",
                 cost: Optional[CostModel] = None, max_group: int = 16,
                 sched_cfg: SchedulerConfig = SchedulerConfig(),
                 plan_capacity: int = 128):
        assert mode in ("time", "batched", "vliw")
        self.tenants = {t.name: t for t in tenants}
        self.mode = mode
        self.cost = cost or CostModel(TPUV5E)
        # plan_capacity bounds the JIT's persistent plan caches (program
        # templates + block plans); 0 = rebuild per step (baseline)
        self.jit = VLIWJit(self.cost, sched_cfg=sched_cfg,
                           max_group=max_group, plan_capacity=plan_capacity)
        self.jit_stats = JitStats()
        for t in tenants:
            t.cache = t.model.init_cache(t.max_batch, t.cache_len)
            t.slot_req = [None] * t.max_batch
            t.slot_tok = jnp.zeros((t.max_batch, 1), jnp.int32)
            t.slot_remaining = [0] * t.max_batch

    # ------------------------------------------------------------------
    # modeled step times
    # ------------------------------------------------------------------
    def _ops_time(self, cfg: ModelConfig, m: int) -> float:
        """Serial modeled time for one full decode step at batch m."""
        t = 0.0
        for tag, shape in gemm_population(cfg, m):
            reps = 1 if tag == "unembed" else cfg.num_layers
            t += reps * self.cost.gemm_time(shape)
        return t + self._attn_time(cfg, m)

    def _attn_time(self, cfg: ModelConfig, m: int) -> float:
        """KV-cache streaming time (memory-bound), same for every mode."""
        if cfg.is_attention_free:
            return 0.0
        hd = cfg.resolved_head_dim
        # mean filled length ~ half the cache
        mean_len = 0.5 * max(t.cache_len for t in self.tenants.values()
                             if t.cfg is cfg) if any(
            t.cfg is cfg for t in self.tenants.values()) else 64
        bytes_ = 2 * cfg.num_layers * cfg.num_kv_heads * mean_len * hd * 2 * m
        return bytes_ / self.cost.device.hbm_bw

    def _prefill_time(self, cfg: ModelConfig, prompt_len: int) -> float:
        t = 0.0
        for tag, shape in gemm_population(cfg, prompt_len):
            reps = 1 if tag == "unembed" else cfg.num_layers
            t += reps * self.cost.gemm_time(shape)
        return t

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _admit(self, tenant: Tenant, req: ServeRequest, rng: jax.Array,
               now: float) -> float:
        """Prefill ``req`` into the tenant. Returns the modeled prefill time
        (0.0 with ``tokens_out`` still None means: no free slot, retry).

        A request whose prefill already produced its only token
        (``max_new_tokens <= 1``) is retired here, at admission, in every
        mode: it never occupies a decode slot, so it cannot join a decode
        step it does not need (which used to inflate its latency by one
        step and emit an extra token). ``finish_t`` is set for the caller
        to count it as done."""
        needs_slot = req.max_new_tokens > 1
        slots = [i for i, r in enumerate(tenant.slot_req) if r is None]
        if needs_slot and not slots:
            return 0.0  # caller retries later
        m = tenant.model
        prompt = jax.random.randint(jax.random.fold_in(rng, req.req_id),
                                    (1, req.prompt_len), 0,
                                    m.cfg.vocab_size)
        pbatch = {"tokens": prompt}
        if m.cfg.arch_type == "vlm":
            pbatch["patch_embeds"] = jnp.zeros(
                (1, m.cfg.num_patch_tokens, m.cfg.d_model), m.dtype)
        if m.cfg.is_encdec:
            pbatch["frames"] = jnp.zeros(
                (1, m.cfg.encoder_seq_len, m.cfg.d_model), m.dtype)
        logits, pc = m.prefill(tenant.params, pbatch,
                               cache_len=tenant.cache_len)
        tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        req.tokens_out = [int(tok)]
        dt = self._prefill_time(m.cfg, req.prompt_len)
        if not needs_slot:
            req.finish_t = now + dt    # done at admission: no decode steps
            return dt
        # write row into the tenant's slotted cache
        slot = slots[0]
        new_layers = {}
        for key, arr in tenant.cache["layers"].items():
            new_layers[key] = arr.at[:, slot].set(pc["layers"][key][:, 0])
        tenant.cache = {
            "pos": tenant.cache["pos"].at[slot].set(pc["pos"][0]),
            "layers": new_layers,
        }
        tenant.slot_tok = tenant.slot_tok.at[slot, 0].set(tok)
        tenant.slot_req[slot] = req
        tenant.slot_remaining[slot] = req.max_new_tokens - 1
        return dt

    # ------------------------------------------------------------------
    # one decode round (baseline modes only)
    # ------------------------------------------------------------------
    def _decode_round(self) -> float:
        live = [t for t in self.tenants.values() if t.active_slots()]
        dt = 0.0
        if self.mode == "batched":
            for t in live:
                dt += self._tenant_batched_step(t)
        else:  # time: every active request decodes alone, serialized
            for t in live:
                n_active = len(t.active_slots())
                logits, t.cache = t.model.decode_step(t.params, t.slot_tok,
                                                      t.cache)
                self._consume(t, logits)
                dt += n_active * self._ops_time(t.cfg, 1)
        return dt

    def _tenant_batched_step(self, t: Tenant) -> float:
        logits, t.cache = t.model.decode_step(t.params, t.slot_tok, t.cache)
        self._consume(t, logits)
        return self._ops_time(t.cfg, len(t.active_slots()))

    def _consume(self, t: Tenant, logits: jax.Array) -> None:
        toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        t.slot_tok = toks[:, None]
        for slot in t.active_slots():
            req = t.slot_req[slot]
            req.tokens_out.append(int(toks[slot]))
            t.slot_remaining[slot] -= 1

    def _retire(self, t: Tenant, now: float) -> int:
        """Free slots of finished requests; returns how many retired."""
        done = 0
        for slot in t.active_slots():
            if t.slot_remaining[slot] <= 0:
                req = t.slot_req[slot]
                req.finish_t = now
                t.slot_req[slot] = None
                done += 1
        return done

    # ------------------------------------------------------------------
    # the event loop (vliw mode)
    # ------------------------------------------------------------------
    def _jit_capable(self, t: Tenant) -> bool:
        # layerwise kernel programs support dense bf16/f32 caches;
        # int8-KV tenants take the monolithic batched step
        return t.cfg.arch_type in ("dense", "vlm") \
            and not getattr(t.model, "kv_quant", False)

    def _build_program(self, t: Tenant, stream_id: int, now: float
                       ) -> KernelProgram:
        """Bind the tenant's next decode step, carrying the tightest
        *this-step* deadline of its batch into the program.

        Steady-state hot path: the compiled ``ProgramTemplate`` (stage
        list + glue closures + weight keys) comes from the JIT's persistent
        plan cache keyed by (model identity, batch m, dtype, cache
        geometry) and identity-guarded on ``(t.model, t.params)`` — only the per-step
        env (tokens, KV cache refs, deadlines) is rebuilt per tick, so the
        cache misses only on the first step, a batch-size change, or a
        weight hot-swap.

        A request's final deadline is discounted by the modeled time of its
        decode steps still to come AFTER this one, so the scheduler's slack
        (and therefore its WAIT budget) reflects whole-request progress,
        not just the current step's GEMM suffix — otherwise a request with
        zero end-to-end slack would look staggerable at every step.

        Already-missed requests are ignored while a healthy batchmate
        exists — one hopeless straggler must not demote the whole tenant's
        programs from EDF anchoring and cascade misses onto requests that
        still have slack. Only when every batched request has missed does
        the program carry the raw (past) final deadline; that value is
        step-invariant, which the scheduler's per-(stream, deadline)
        eviction dedup relies on."""
        reqs = [(t.slot_req[s], t.slot_remaining[s])
                for s in t.active_slots()]
        # one full decode step (GEMMs + KV streaming; _ops_time includes
        # _attn_time already)
        step_t = self._ops_time(t.cfg, t.max_batch)
        finals = [r.arrival_t + r.slo_s for r, _ in reqs]
        step_deadlines = [f - max(rem - 1, 0) * step_t
                          for f, (_, rem) in zip(finals, reqs)]
        future = [d for d in step_deadlines if d > now]
        deadline = min(future) if future else \
            min(finals) if finals else math.inf
        batch = int(t.slot_tok.shape[0])
        template = self.jit.plan_cache.get_or_build(
            dense_program_cache_key(t.model, t.params, batch, t.cache),
            lambda: build_dense_decode_template(t.model, t.params, batch),
            guard=(t.model, t.params), group=("tenant", t.name))
        return template.bind(
            stream_id=stream_id, tokens=t.slot_tok, cache=t.cache,
            arrival_t=now, deadline_t=deadline,
            req_deadlines=tuple((r.req_id, f)
                                for (r, _), f in zip(reqs, finals)))

    def _run_event_loop(self, pending: List[ServeRequest], rng: jax.Array
                        ) -> float:
        session = self.jit.session()
        stream_ids = {name: i for i, name in enumerate(self.tenants)}
        id2name = {i: name for name, i in stream_ids.items()}
        inflight: Dict[str, KernelProgram] = {}
        waiting: List[ServeRequest] = []   # due but not yet admissible
        now, pi, n_done = 0.0, 0, 0
        total = len(pending)
        while True:
            progressed = False
            # 1. live admission: prefill every due request into its tenant's
            #    slotted cache (the device serializes on prefills). A tenant
            #    with a program inflight (or full slots) admits at its next
            #    step boundary — prefilling under an inflight program would
            #    be clobbered by its write-back — but other tenants' due
            #    requests are admitted past it, not blocked behind it.
            while pi < len(pending) and pending[pi].arrival_t <= now:
                waiting.append(pending[pi])
                pi += 1
            still: List[ServeRequest] = []
            for req in waiting:
                t = self.tenants[req.tenant]
                if req.tenant in inflight:
                    still.append(req)
                    continue
                dt = self._admit(t, req, rng, now)
                if dt == 0.0 and req.tokens_out is None:
                    still.append(req)  # tenant slots full; retry later
                    continue
                now += dt
                if not math.isnan(req.finish_t):
                    n_done += 1        # retired at admission (single token)
                progressed = True
            waiting = still
            session.set_next_arrival(pending[pi].arrival_t
                                     if pi < len(pending) else math.inf)

            # 2. every JIT-capable tenant with live requests keeps a program
            #    in the pool — admitted between dispatches, not per round
            for name, t in self.tenants.items():
                if self._jit_capable(t) and name not in inflight \
                        and t.active_slots():
                    prog = self._build_program(t, stream_ids[name], now)
                    inflight[name] = prog
                    session.admit(prog)
                    progressed = True

            # 3. one scheduler decision on the shared virtual clock
            ev = session.tick(now)
            progressed |= ev.kind != "idle"
            now = max(now, ev.t)
            for prog in ev.completed:
                t = self.tenants[id2name[prog.stream_id]]
                del inflight[id2name[prog.stream_id]]
                t.cache = prog.env["cache"]
                self._consume(t, prog.env["logits"][:, None, :])
                now += self._attn_time(t.cfg, t.max_batch)
                n_done += self._retire(t, now)

            # 4. non-JIT tenants interleave monolithic batched steps
            for t in self.tenants.values():
                if not self._jit_capable(t) and t.active_slots():
                    now += self._tenant_batched_step(t)
                    n_done += self._retire(t, now)
                    progressed = True

            if n_done >= total and not session.live and pi >= len(pending) \
                    and not waiting:
                break
            if not progressed:
                if pi < len(pending):
                    now = max(now, pending[pi].arrival_t)
                    continue
                if not waiting:
                    break
        self.jit_stats.merge(session.stats)
        return now

    # ------------------------------------------------------------------
    # round loop (baseline modes: rounds ARE their semantics)
    # ------------------------------------------------------------------
    def _run_rounds(self, pending: List[ServeRequest], rng: jax.Array
                    ) -> float:
        now, pi, n_done = 0.0, 0, 0
        while n_done < len(pending):
            progressed = False
            while pi < len(pending) and pending[pi].arrival_t <= now:
                req = pending[pi]
                t = self.tenants[req.tenant]
                dt = self._admit(t, req, rng, now)
                if dt == 0.0 and req.tokens_out is None:
                    break  # tenant full; retry after this round
                now += dt
                if not math.isnan(req.finish_t):
                    n_done += 1        # retired at admission (single token)
                pi += 1
                progressed = True
            dt = self._decode_round()
            if dt == 0.0 and not progressed:
                if pi < len(pending):
                    now = max(now, pending[pi].arrival_t)
                    continue
                break
            now += dt
            for t in self.tenants.values():
                n_done += self._retire(t, now)
        return now

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[ServeRequest],
            rng: Optional[jax.Array] = None) -> ServeReport:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        pending = sorted(trace, key=lambda r: r.arrival_t)
        wall0 = _time.perf_counter()
        if self.mode == "vliw":
            makespan = self._run_event_loop(pending, rng)
        else:
            makespan = self._run_rounds(pending, rng)
        wall = _time.perf_counter() - wall0
        return ServeReport(self.mode, list(trace), makespan, wall,
                           jit=self.jit_stats if self.mode == "vliw" else None)
