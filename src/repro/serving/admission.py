"""SLO-tiered admission control for the serving front door.

The engine's replay loop admits every request and lets EDF + eviction sort
out overload — under sustained open-loop traffic past the saturation knee
that degrades EVERY request together (queueing delay grows without bound,
attainment collapses toward zero). The front door instead makes an explicit
admit / degrade / shed decision per request AT ADMISSION, from the same
analytic cost model the scheduler plans with ("ML Inference Scheduling with
Predictable Latency", PAPERS.md):

  * the modeled service cost of the request (prefill + remaining decode
    steps, amortized at the tenant's batch width) is known up front;
  * the device's committed backlog (virtual completion horizon of
    everything already admitted to it) is tracked by the engine;
  * the ``ArrivalPredictor`` EWMA forecasts near-term load — when the
    offered utilization rho = cost / inter-arrival-gap exceeds 1, the
    queue is forecast to GROW during this request's service, so the
    admission bar tightens by the forecast growth.

A request is admitted iff its forecast completion (now + backlog + cost +
overload margin) meets its tier's deadline. When it cannot, the controller
walks DOWN the tier ladder (``TierSpec.slo_scale`` relaxes the deadline)
and degrades the request to the first tier whose deadline is feasible —
the request is still served, with a relaxed, *kept* promise — and only
sheds when no tier works. Shed requests never occupy a slot; they are
counted as SLO misses in ``ServeReport.slo_attainment`` (never silently
dropped from the denominator).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence, Tuple

from repro.serving.workload import ServeRequest


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One rung of the SLO ladder. ``slo_scale`` multiplies a request's
    base (tier-normalized) SLO budget; ``sheddable=False`` marks a tier
    the door must admit best-effort rather than shed (its misses then show
    up honestly in attainment)."""
    name: str
    slo_scale: float = 1.0
    sheddable: bool = True


DEFAULT_TIERS: Tuple[TierSpec, ...] = (
    TierSpec("interactive", 1.0),
    TierSpec("standard", 2.0),
    TierSpec("batch", 6.0),
)


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    action: str          # "admit" | "degrade" | "shed"
    tier: int            # final tier (== request tier unless degrading)
    slo_s: float         # final SLO budget at that tier
    eta_s: float         # forecast completion the decision was made on
    deadline_s: float    # deadline the request was judged against


@dataclasses.dataclass
class AdmissionController:
    """Predictable-latency admit/degrade/shed policy (front-door brain).

    ``decide`` is pure w.r.t. engine state — the engine supplies the
    modeled request cost, the device's committed backlog and the tenant's
    EWMA inter-arrival gap; the controller only applies the tier ladder.
    ``safety`` scales the overload-forecast margin (0 disables the
    ArrivalPredictor term, leaving a plain backlog-vs-deadline test).
    """

    tiers: Sequence[TierSpec] = DEFAULT_TIERS
    allow_degrade: bool = True
    safety: float = 1.0
    # door accounting (per ORIGINAL tier): admitted / degraded / shed
    counts: Dict[str, Dict[int, int]] = dataclasses.field(
        default_factory=lambda: {"admit": {}, "degrade": {}, "shed": {}})

    def _count(self, action: str, tier: int) -> None:
        self.counts[action][tier] = self.counts[action].get(tier, 0) + 1

    def decide(self, req: ServeRequest, now: float, backlog_s: float,
               cost_s: float, gap_s: float) -> AdmissionDecision:
        """Judge one due request at the door.

        ``backlog_s``: committed-but-unfinished modeled work ahead of it on
        its home device. ``gap_s``: the tenant's EWMA inter-arrival gap
        (inf until the predictor has seen a gap). The overload margin is
        max(rho - 1, 0) * cost_s: while this request is in service, rho
        * cost_s of new work is forecast to arrive, of which capacity
        absorbs cost_s — the excess is queue growth it must outlive."""
        tier = min(max(req.tier, 0), len(self.tiers) - 1)
        rho = cost_s / gap_s if (gap_s > 0.0 and math.isfinite(gap_s)) \
            else 0.0
        margin = max(rho - 1.0, 0.0) * cost_s * self.safety
        eta = now + backlog_s + cost_s + margin
        # tier-normalized base budget, so deadlines relax monotonically
        # down the ladder regardless of the tier the request entered at
        base = req.slo_s / self.tiers[tier].slo_scale
        last = len(self.tiers) if self.allow_degrade else tier + 1
        for j in range(tier, last):
            slo_j = base * self.tiers[j].slo_scale
            deadline = req.arrival_t + slo_j
            if eta <= deadline:
                action = "admit" if j == tier else "degrade"
                self._count(action, tier)
                return AdmissionDecision(action, j, slo_j, eta, deadline)
        deadline = req.arrival_t + req.slo_s
        if not self.tiers[tier].sheddable:
            # best-effort admit: the miss will be visible in attainment
            self._count("admit", tier)
            return AdmissionDecision("admit", tier, req.slo_s, eta, deadline)
        self._count("shed", tier)
        return AdmissionDecision("shed", tier, req.slo_s, eta, deadline)

    @property
    def n_shed(self) -> int:
        return sum(self.counts["shed"].values())

    @property
    def n_degraded(self) -> int:
        return sum(self.counts["degrade"].values())
