"""The serving front door: clocks, tickets and the request intake queue
for ``ServingEngine.serve_forever`` (the long-lived daemon mode).

Replay (``engine.run``) consumes a finite trace and terminates when it is
exhausted; the daemon instead serves whatever arrives at a ``FrontDoor``
until the door is CLOSED, idling (not exiting) while the door is open and
empty, and flushing in-flight work before returning once it closes.

Two clock families drive the loop:

  * ``MonotonicClock`` — the real wall clock (``authoritative=True``): the
    per-device virtual timelines are floored at real elapsed time every
    iteration, so arrival stamps, deadlines and modeled service charges
    share one axis. This is the production daemon.
  * ``VirtualClock`` — a follower clock for tests and the sustained-load
    benchmark: it only ever advances to what the modeled device timelines
    (or an idle sleep) tell it, so a door pre-loaded with a scheduled
    trace replays deterministically, with exactly the per-device clock
    semantics of ``engine.run``.

``FrontDoor.submit`` is thread-safe: a feeder thread may push requests
while the daemon loop runs (``at=None`` stamps the arrival at the poll
that releases it); tests and benches pre-schedule submissions with
``at=t`` instead. Each submission returns a ``Ticket`` that streams the
request's tokens out as they retire (``on_token`` callback or the
``tokens`` list) — the per-request streaming surface of the daemon.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.workload import ServeRequest


class MonotonicClock:
    """Real wall clock, zeroed at construction. Authoritative: device
    virtual timelines are floored at ``now()`` so modeled charges accrue
    on top of real elapsed time."""

    authoritative = True

    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def sleep_until(self, t: float) -> None:
        # capped sleep: a feeder thread may submit (or close the door)
        # while we wait, so never commit to a long uninterruptible nap
        dt = t - self.now()
        if dt > 0.0:
            time.sleep(min(dt, 0.05))

    def advance_to(self, t: float) -> None:
        """No-op: real time advances itself."""


class VirtualClock:
    """Deterministic follower clock (tests / benches): ``advance_to``
    tracks the modeled device timelines, ``sleep_until`` jumps idle time
    instantly. Never moves backwards."""

    authoritative = False

    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def now(self) -> float:
        return self.t

    def sleep_until(self, t: float) -> None:
        self.t = max(self.t, t)

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, t)


@dataclasses.dataclass
class Ticket:
    """Per-request streaming handle returned by ``FrontDoor.submit``."""

    request: ServeRequest
    on_token: Optional[Callable[[int, float], None]] = None
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def shed(self) -> bool:
        return self.request.shed

    @property
    def done(self) -> bool:
        """Finished OR shed — either way the door owes nothing further."""
        return self.request.shed or not math.isnan(self.request.finish_t)


class DoorClosed(RuntimeError):
    """Raised by ``submit`` after the door has closed."""


class FrontDoor:
    """Thread-safe request intake for the serving daemon.

    Lifecycle: ``submit`` requests (live, or pre-scheduled with ``at=``),
    then ``close()`` (or construct the closing time up front with
    ``close(at=...)``). Submissions accepted before closing are always
    honored — closing stops NEW intake; the daemon drains what was
    accepted, flushes in-flight work and returns.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # (release time, submit seq, request); -inf = release on next poll
        self._heap: List[Tuple[float, int, ServeRequest]] = []
        self._seq = 0
        self._closed = False
        self.close_at: Optional[float] = None
        self.tickets: Dict[int, Ticket] = {}

    # -- intake --------------------------------------------------------
    def submit(self, req: ServeRequest, *, at: Optional[float] = None,
               on_token: Optional[Callable[[int, float], None]] = None
               ) -> Ticket:
        """Queue ``req`` for admission. ``at=None`` releases it at the
        next daemon poll (arrival stamped then); ``at=t`` schedules the
        arrival at clock time ``t``. Returns the request's ``Ticket``."""
        with self._lock:
            if self._closed:
                raise DoorClosed("front door is closed")
            if req.req_id in self.tickets:
                raise ValueError(
                    f"duplicate req_id {req.req_id} at the door — request "
                    f"ids key prompt synthesis and retirement accounting")
            ticket = Ticket(req, on_token=on_token)
            self.tickets[req.req_id] = ticket
            heapq.heappush(self._heap,
                           (at if at is not None else -math.inf,
                            self._seq, req))
            self._seq += 1
            return ticket

    def close(self, at: Optional[float] = None) -> None:
        """Stop accepting new submissions. ``at=t`` defers the closing to
        clock time ``t`` (already-accepted scheduled submissions are still
        released either way)."""
        with self._lock:
            if at is None:
                self._closed = True
            else:
                self.close_at = at if self.close_at is None \
                    else min(self.close_at, at)

    # -- daemon side ---------------------------------------------------
    def poll(self, now: float) -> List[ServeRequest]:
        """Release every submission due at clock time ``now``, stamping
        un-scheduled ones with ``arrival_t = now``."""
        out: List[ServeRequest] = []
        with self._lock:
            if self.close_at is not None and now >= self.close_at:
                self._closed = True
            while self._heap and self._heap[0][0] <= now:
                at, _, req = heapq.heappop(self._heap)
                req.arrival_t = at if math.isfinite(at) else now
                out.append(req)
        return out

    def next_arrival(self, now: float) -> Optional[float]:
        """Earliest scheduled release still queued (None if empty or the
        head is an unscheduled live submission, which is due NOW)."""
        with self._lock:
            if not self._heap:
                return None
            at = self._heap[0][0]
            return at if math.isfinite(at) else now

    def closed(self, now: float) -> bool:
        with self._lock:
            # a deferred close LATCHES once any clock-bearing caller
            # observes the deadline passed — submit() has no clock, so the
            # latch is what makes it start refusing
            if self.close_at is not None and now >= self.close_at:
                self._closed = True
            return self._closed

    def drained(self) -> bool:
        with self._lock:
            return not self._heap

    def finished(self, now: float) -> bool:
        """Closed AND drained: the daemon may flush and return."""
        return self.closed(now) and self.drained()

    # -- streaming sink (wired as the engine's token_sink) -------------
    def deliver(self, req: ServeRequest, tok: int, t: float) -> None:
        ticket = self.tickets.get(req.req_id)
        if ticket is None:
            return
        ticket.tokens.append(tok)
        if ticket.on_token is not None:
            ticket.on_token(tok, t)
