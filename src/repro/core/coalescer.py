"""Superkernel planning (paper §5.3 "VLIW compilation").

A ``SuperkernelPlan`` is the VLIW instruction word: a set of mutually
independent GEMM problems (from different streams) packed for one dispatch.
The coalescer checks feasibility (VMEM footprint of the tile working set,
padding waste bound), picks the block config (from the autotuner's table if
present), and estimates the dispatch latency with the cost model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.clustering import Cluster, exact_key
from repro.core.costmodel import BlockConfig, CostModel, DEFAULT_BLOCK, GemmShape
from repro.core.kernelspec import KernelOp
from repro.core.plancache import PlanCache


@dataclasses.dataclass
class SuperkernelPlan:
    ops: List[KernelOp]
    block: BlockConfig
    est_time_s: float
    padding_waste: float
    shared_operand: bool = False

    @property
    def shapes(self) -> List[GemmShape]:
        return [o.shape for o in self.ops]

    @property
    def num_problems(self) -> int:
        return len(self.ops)


class Coalescer:
    """Packs ready, shape-compatible ops into superkernel plans."""

    def __init__(self, cost: CostModel, max_group: int = 64,
                 max_waste: float = 0.25,
                 tuned_blocks: Optional[Dict[Tuple, BlockConfig]] = None,
                 memo: Optional[PlanCache] = None, *, device_id: int = 0):
        self.cost = cost
        self.max_group = max_group
        self.max_waste = max_waste
        self.tuned_blocks = tuned_blocks or {}
        # optional block-plan memo (core/plancache.py): the JIT re-plans the
        # same coalesced group signatures on every dispatch of a steady-state
        # decode loop, so (block config, padding waste, modeled latency) are
        # memoized per (ordered shape tuple, shared-operand) key
        self.memo = memo
        # which mesh device this coalescer plans for. The memo may be
        # SHARED across the per-device coalescers (one VLIWJit-owned
        # PlanCache), so the device id is part of every memo key: two
        # devices with different tenant mixes — or heterogeneous device
        # profiles — must never serve each other's block plans (see
        # tests/test_multi_device.py's pre-fix-failing regression).
        self.device_id = device_id

    # ------------------------------------------------------------------
    def block_for(self, shapes: Sequence[GemmShape]) -> BlockConfig:
        key = exact_key(shapes[0])
        if key in self.tuned_blocks:
            return self.tuned_blocks[key]
        # default: clamp tile to the (padded) problem size, MXU-aligned
        n = max(s.n for s in shapes)
        m = max(s.m for s in shapes)
        bm = min(128, max(8, 1 << (max(m - 1, 1)).bit_length()))
        bn = min(128, max(128, n)) if n >= 128 else n
        return BlockConfig(bm=bm, bn=max(bn, 8), bk=DEFAULT_BLOCK.bk)

    def vmem_ok(self, shapes: Sequence[GemmShape], block: BlockConfig) -> bool:
        k = max(s.k for s in shapes)
        return block.vmem_usage(k) <= self.cost.device.vmem_bytes

    # ------------------------------------------------------------------
    def plan(self, ops: Sequence[KernelOp]) -> SuperkernelPlan:
        """Plan a superkernel for an already-compatible op group."""
        ops = list(ops)[: self.max_group]
        shapes = [o.shape for o in ops]
        # same weights across streams (same model+tag) => operand sharing
        shared = len({(o.model_id, o.tag, o.seq_index) for o in ops}) == 1 \
            and len(ops) > 1
        # layer-stacked groups (clustering.coalesce_key buckets them on the
        # full stack signature, so a group is either all-stacked with one
        # signature or all-plain): charge the group slot-by-slot — each
        # operand position of the scanned body is one coalesced wave-train
        # across the member streams, run sequentially
        stacks = [o.stack for o in ops]
        stacked = all(s is not None for s in stacks) and len(
            {tuple((t_, sh.layers, sh.n, sh.k, sh.dtype_bytes)
                   for t_, sh in s) for s in stacks}) == 1

        def derive() -> Tuple[BlockConfig, float, float]:
            if stacked:
                t = 0.0
                useful = padded = 0.0
                block = None
                for slot in zip(*stacks):
                    slot_shapes = [sh for _, sh in slot]
                    c = Cluster(slot_shapes)
                    useful += c.useful_flops
                    padded += c.padded_flops
                    b = self.block_for(slot_shapes)
                    if block is None:
                        block = b
                    t += self.cost.coalesced_time(slot_shapes, b,
                                                  shared_operand=shared)
                waste = 0.0 if padded == 0 else 1.0 - useful / padded
                return block or self.block_for(shapes), waste, t
            block = self.block_for(shapes)
            return (block, Cluster(list(shapes)).padding_waste,
                    self.cost.coalesced_time(shapes, block,
                                             shared_operand=shared))

        if self.memo is not None:
            key = ("block", self.device_id,
                   tuple((s.m, s.n, s.k, s.dtype_bytes, s.layers)
                         for s in shapes),
                   tuple(tuple((t_, sh.m, sh.layers, sh.n, sh.k,
                                sh.dtype_bytes) for t_, sh in st)
                         for st in stacks) if stacked else None,
                   shared)
            block, waste, t = self.memo.get_or_build(key, derive)
        else:
            block, waste, t = derive()
        # cross-device collective charge (MoE expert dispatch/combine for
        # device-spanning tenants): added OUTSIDE the memo so the memoized
        # entry stays a pure-GEMM time — the collective depends on the
        # member ops, not the shape signature
        coll = max((op.collective_s for op in ops), default=0.0)
        return SuperkernelPlan(ops=ops, block=block, est_time_s=t + coll,
                               padding_waste=waste, shared_operand=shared)

    # ------------------------------------------------------------------
    def speedup_vs_serial(self, plan: SuperkernelPlan) -> float:
        t_serial = self.cost.time_multiplexed(plan.shapes, plan.block)
        return t_serial / plan.est_time_s if plan.est_time_s > 0 else 1.0

    def marginal_gain(self, base_ops: Sequence[KernelOp],
                      extra: KernelOp) -> float:
        """Time saved by adding ``extra`` to the group vs running it alone."""
        t_alone = self.cost.gemm_time(extra.shape)
        t_base = self.plan(list(base_ops)).est_time_s if base_ops else 0.0
        t_joint = self.plan(list(base_ops) + [extra]).est_time_s
        return (t_base + t_alone) - t_joint
