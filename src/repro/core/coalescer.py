"""Superkernel planning (paper §5.3 "VLIW compilation").

A ``SuperkernelPlan`` is the VLIW instruction word: a set of mutually
independent GEMM problems (from different streams) packed for one dispatch.
The coalescer checks feasibility (VMEM footprint of the tile working set,
padding waste bound), picks the block config (from the autotuner's table if
present), and estimates the dispatch latency with the cost model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.autotuner import LiveTuner
from repro.core.clustering import Cluster, exact_key
from repro.core.costmodel import BlockConfig, CostModel, DEFAULT_BLOCK, GemmShape
from repro.core.kernelspec import KernelOp
from repro.core.plancache import PlanCache


@dataclasses.dataclass
class SuperkernelPlan:
    ops: List[KernelOp]
    block: BlockConfig
    est_time_s: float
    padding_waste: float
    shared_operand: bool = False

    @property
    def shapes(self) -> List[GemmShape]:
        return [o.shape for o in self.ops]

    @property
    def num_problems(self) -> int:
        return len(self.ops)


class Coalescer:
    """Packs ready, shape-compatible ops into superkernel plans."""

    def __init__(self, cost: CostModel, max_group: int = 64,
                 max_waste: float = 0.25,
                 tuned_blocks: Optional[Dict[Tuple, BlockConfig]] = None,
                 memo: Optional[PlanCache] = None, *, device_id: int = 0,
                 tuner: Optional[LiveTuner] = None):
        self.cost = cost
        self.max_group = max_group
        self.max_waste = max_waste
        self.tuned_blocks = tuned_blocks or {}
        # live autotuner (core/autotuner.LiveTuner): when present it
        # REPLACES both the AOT table and the static heuristic — every
        # block_for consults it (a tune-cache lookup per call, an
        # exhaustive cost-model search only on a never-seen signature)
        self.tuner = tuner
        # optional block-plan memo (core/plancache.py): the JIT re-plans the
        # same coalesced group signatures on every dispatch of a steady-state
        # decode loop, so (block config, padding waste, modeled latency) are
        # memoized per (ordered shape tuple, shared-operand) key
        self.memo = memo
        # which mesh device this coalescer plans for. The memo may be
        # SHARED across the per-device coalescers (one VLIWJit-owned
        # PlanCache), so the device id is part of every memo key: two
        # devices with different tenant mixes — or heterogeneous device
        # profiles — must never serve each other's block plans (see
        # tests/test_multi_device.py's pre-fix-failing regression).
        self.device_id = device_id

    # ------------------------------------------------------------------
    def block_for(self, shapes: Sequence[GemmShape], *,
                  shared_operand: bool = False) -> BlockConfig:
        if self.tuner is not None:
            return self.tuner.tune(shapes, shared_operand=shared_operand)
        # AOT table lookup keyed on the FULL group signature: the table is
        # per-shape (exact_key), so it only applies when every member
        # shares that one key — a tile tuned for shape s0 alone must not
        # be imposed on a mixed group whose envelope is the max over
        # members (pre-fix this keyed on shapes[0] only, silently
        # mis-tiling every other member; see tests/test_live_tuner.py's
        # regression).
        keys = {exact_key(s) for s in shapes}
        if len(keys) == 1:
            key = next(iter(keys))
            if key in self.tuned_blocks:
                return self.tuned_blocks[key]
        # default: clamp tile to the (padded) problem size, MXU-aligned
        n = max(s.n for s in shapes)
        m = max(s.m for s in shapes)
        bm = min(128, max(8, 1 << (max(m - 1, 1)).bit_length()))
        return BlockConfig(bm=bm, bn=max(8, min(128, n)),
                           bk=DEFAULT_BLOCK.bk)

    def vmem_ok(self, shapes: Sequence[GemmShape], block: BlockConfig) -> bool:
        k = max(s.k for s in shapes)
        return block.vmem_usage(k) <= self.cost.device.vmem_bytes

    # ------------------------------------------------------------------
    def plan(self, ops: Sequence[KernelOp]) -> SuperkernelPlan:
        """Plan a superkernel for an already-compatible op group."""
        ops = list(ops)[: self.max_group]
        shapes = [o.shape for o in ops]
        # same weights across streams (same model+tag) => operand sharing
        shared = len({(o.model_id, o.tag, o.seq_index) for o in ops}) == 1 \
            and len(ops) > 1
        # layer-stacked groups (clustering.coalesce_key buckets them on the
        # full stack signature, so a group is either all-stacked with one
        # signature or all-plain): charge the group slot-by-slot — each
        # operand position of the scanned body is one coalesced wave-train
        # across the member streams, run sequentially
        stacks = [o.stack for o in ops]
        stacked = all(s is not None for s in stacks) and len(
            {tuple((t_, sh.layers, sh.n, sh.k, sh.dtype_bytes)
                   for t_, sh in s) for s in stacks}) == 1

        def derive() -> Tuple[BlockConfig, float, float]:
            if stacked:
                t = 0.0
                useful = padded = 0.0
                block = None
                for slot in zip(*stacks):
                    slot_shapes = [sh for _, sh in slot]
                    c = Cluster(slot_shapes)
                    useful += c.useful_flops
                    padded += c.padded_flops
                    b = self.block_for(slot_shapes, shared_operand=shared)
                    if block is None:
                        block = b
                    t += self.cost.coalesced_time(slot_shapes, b,
                                                  shared_operand=shared)
                waste = 0.0 if padded == 0 else 1.0 - useful / padded
                return (block or self.block_for(shapes,
                                                shared_operand=shared),
                        waste, t)
            block = self.block_for(shapes, shared_operand=shared)
            return (block, Cluster(list(shapes)).padding_waste,
                    self.cost.coalesced_time(shapes, block,
                                             shared_operand=shared))

        # live tuning consults the tuner on EVERY plan (a tune-cache hit
        # per dispatch in steady state — the gated hit-rate criterion),
        # and the tuned block joins the memo key: a re-tune that changed
        # the config can never be served a stale memoized (waste, time)
        tuned = None
        if self.tuner is not None:
            rep = [sh for _, sh in next(zip(*stacks))] if stacked \
                else shapes
            tuned = self.block_for(rep, shared_operand=shared)
        if self.memo is not None:
            key = ("block", self.device_id,
                   tuple((s.m, s.n, s.k, s.dtype_bytes, s.layers)
                         for s in shapes),
                   tuple(tuple((t_, sh.m, sh.layers, sh.n, sh.k,
                                sh.dtype_bytes) for t_, sh in st)
                         for st in stacks) if stacked else None,
                   shared,
                   None if tuned is None else (tuned.bm, tuned.bn,
                                               tuned.bk))
            block, waste, t = self.memo.get_or_build(key, derive)
        else:
            block, waste, t = derive()
        # cross-device collective charge (MoE expert dispatch/combine for
        # device-spanning tenants): added OUTSIDE the memo so the memoized
        # entry stays a pure-GEMM time — the collective depends on the
        # member ops, not the shape signature
        coll = max((op.collective_s for op in ops), default=0.0)
        return SuperkernelPlan(ops=ops, block=block, est_time_s=t + coll,
                               padding_waste=waste, shared_operand=shared)

    # ------------------------------------------------------------------
    def speedup_vs_serial(self, plan: SuperkernelPlan) -> float:
        t_serial = self.cost.time_multiplexed(plan.shapes, plan.block)
        return t_serial / plan.est_time_s if plan.est_time_s > 0 else 1.0

    def marginal_gain(self, base_ops: Sequence[KernelOp],
                      extra: KernelOp) -> float:
        """Time saved by adding ``extra`` to the group vs running it alone."""
        t_alone = self.cost.gemm_time(extra.shape)
        t_base = self.plan(list(base_ops)).est_time_s if base_ops else 0.0
        t_joint = self.plan(list(base_ops) + [extra]).est_time_s
        return (t_base + t_alone) - t_joint
