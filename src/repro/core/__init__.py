"""The paper's primary contribution: the OoO VLIW JIT.

kernelspec — declarative dispatch IR (§5.1); clustering — Fig. 7 shape
clusters; coalescer — superkernel planning (§5.3); scheduler — OoO EDF +
slack staggering (§5.2); autotuner — greedy vs collaborative AOT tuning
(Table 1); costmodel — calibrated V100 + TPU-v5e roofline device models;
simulator — event-driven multiplexing comparison (Figs 4–6).
"""
from repro.core.autotuner import (Autotuner, LiveTuner, LiveTuneResult,
                                  TuneResult, group_signature)
from repro.core.clustering import Cluster, cluster_greedy, group_ops_exact
from repro.core.coalescer import Coalescer, SuperkernelPlan
from repro.core.costmodel import (BlockConfig, CostModel, Device, GemmShape,
                                  TPUV5E, V100)
from repro.core.dispatch import DispatchStats, SuperkernelExecutor
from repro.core.kernelspec import (GEMV_MAX_ROWS, KernelOp, gemm_population,
                                   make_op, op_aspect, stream_program,
                                   zoo_population)
from repro.core.plancache import PlanCache, PlanCacheStats
from repro.core.scheduler import Decision, OoOScheduler, SchedulerConfig
from repro.core.simulator import (POLICIES, Request, SimResult, make_requests,
                                  simulate_space_mux, simulate_time_mux,
                                  simulate_vliw)

__all__ = [
    "Autotuner", "BlockConfig", "Cluster", "Coalescer", "CostModel",
    "Decision", "Device", "DispatchStats", "GEMV_MAX_ROWS", "GemmShape",
    "KernelOp", "LiveTuneResult", "LiveTuner", "OoOScheduler",
    "PlanCache", "PlanCacheStats", "POLICIES",
    "Request", "SchedulerConfig", "SimResult", "SuperkernelExecutor",
    "SuperkernelPlan", "TPUV5E",
    "TuneResult", "V100", "cluster_greedy", "gemm_population",
    "group_ops_exact", "group_signature", "make_op", "make_requests",
    "op_aspect",
    "simulate_space_mux",
    "simulate_time_mux", "simulate_vliw", "stream_program", "zoo_population",
]
