"""Declarative kernel dispatch IR (paper §5.1).

Instead of "early-binding, context-free" launches, tenants declare WHAT to
compute — a ``KernelOp`` (operator + problem dims + stream + deadline) — and
the JIT owns HOW: binding, packing, ordering. A stream of ``KernelOp``s is
the analogue of a VLIW instruction stream; ops from different streams are
mutually independent by construction (paper §1, reason (b) VLIW fits).

``gemm_population(config, ...)`` enumerates the GEMM problems one
architecture contributes per step — the population clustered in Fig. 7.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.costmodel import GemmShape


@dataclasses.dataclass
class KernelOp:
    """One declared unit of work in a tenant's instruction stream.

    ``kind`` describes the problem's aspect (a tall "gemm" vs a skinny
    "gemv") while ``op_kind`` names the serving phase that declared it
    ("decode" step vs "prefill" prompt pass). Neither partitions the
    coalescing space: the coalesced kernel concatenates problems along m,
    so a 256-row prefill GEMM and a 4-row decode GEMV with the same (n, k)
    pack into one superkernel (clustering.group_ops_exact) — that cross-
    phase packing is the paper's spatial-sharing win applied to prompts.
    """

    op_id: int
    stream_id: int
    kind: str                  # "gemm" | "gemv" | "attn" | "other"
    shape: GemmShape
    arrival_t: float = 0.0
    deadline_t: float = float("inf")
    # intra-stream program order: op i must not run before op i-1 of the same
    # stream has completed (data dependence through the residual stream).
    seq_index: int = 0
    tag: str = ""              # e.g. "qkv_proj", "ffn_up", "expert_gemm"
    model_id: str = ""
    # EDF bookkeeping: the latest virtual time this op can start and still
    # meet its request deadline given the modeled critical path behind it
    # (set by OoOScheduler.annotate_stream / push, or by the JIT from the
    # program's remaining-GEMM suffix).
    latest_start_t: float = float("inf")
    # operand bindings for the real execution path (core/jit.py attaches
    # (activation, weight, weight_key) at admission time); excluded from
    # repr/eq — it carries whole jax arrays
    payload: Optional[Tuple] = dataclasses.field(default=None, repr=False,
                                                 compare=False)
    # per-request identity plumbed from the serving engine through the
    # KernelProgram: (req_id, final deadline) for every request batched
    # into the step this op belongs to. The scheduler uses it to account
    # SLO demotions exactly once per missed request (even one hidden
    # behind a healthy batchmate's anchor deadline); empty for raw op
    # streams, which fall back to (stream, deadline) accounting.
    req_deadlines: Tuple = dataclasses.field(default=(), compare=False)
    # which serving phase declared this op: "decode" (one token against a
    # cache, m = batch) or "prefill" (whole prompt, m = padded prompt
    # length). Purely descriptive for scheduling stats — coalescing
    # eligibility is (n, k, dtype) only.
    op_kind: str = "decode"
    # layer-stacked op (core/jit.py StackedGemmStage): the ordered
    # (operand tag, per-layer GemmShape-with-layers) pairs of ONE scanned
    # layer body covering a homogeneous sub-stack of layers. None for
    # ordinary single-GEMM ops. ``shape`` then holds the DOMINANT operand's
    # shape (for EDF/aspect bookkeeping); coalescing uses the full stack
    # signature (clustering.coalesce_key).
    stack: Optional[Tuple] = dataclasses.field(default=None, repr=False,
                                               compare=False)
    # identity of the KernelProgram INSTANCE that emitted this op (set by
    # JitSession._push_op from KernelProgram.uid; 0 for raw op streams).
    # seq_index alone cannot express program order across a stream's
    # successive step programs — the schedule certifier
    # (repro.analysis.certify) needs (prog_uid, seq) to verify that ops of
    # one program ran in order AND that two programs of one stream never
    # interleaved.
    prog_uid: int = dataclasses.field(default=0, compare=False)
    # placement: which modeled device of the mesh this op is assigned to.
    # Bound at admission (distributed/placement.py via JitSession.device)
    # and immutable afterwards — ops never coalesce across devices
    # (clustering.coalesce_key includes it) and the schedule certifier
    # rejects a dispatch on any other device (PlacementHazard).
    device: int = 0
    # modeled cross-device collective charge attached to this op (seconds):
    # MoE expert dispatch/combine all-to-all for tenants whose expert dim
    # spans devices, TP psum all-reduce when enabled. Charged against EDF
    # slack (latest_start_t) and added to the group's plan estimate — it is
    # NOT part of the memoized pure-GEMM block-plan time.
    collective_s: float = dataclasses.field(default=0.0, compare=False)

    @property
    def slack(self) -> float:
        return self.deadline_t - self.arrival_t


# Aspect boundary: a problem whose activation has at most this many rows is
# a skinny "gemv" (one m-tile of the bm=8 decode superkernel), anything
# taller is a "gemm". This is THE single source of truth — the JIT derives
# the boundary from its configured m-tile (``VLIWJit.bm``) and raw op
# streams fall back to this default; nothing else may hard-code the 8.
GEMV_MAX_ROWS = 8


def op_aspect(m: int, max_gemv_rows: int = GEMV_MAX_ROWS) -> str:
    """Classify a problem's aspect ("gemv" vs "gemm") by its row count.

    ``max_gemv_rows`` is the caller's m-tile: the JIT passes its ``bm`` so
    the classification always matches how the superkernel will actually
    tile the problem."""
    return "gemv" if m <= max_gemv_rows else "gemm"


_OP_COUNTER = itertools.count()


def make_op(stream_id: int, kind: str, shape: GemmShape, *, arrival_t=0.0,
            deadline_t=float("inf"), seq_index=0, tag="", model_id="",
            op_kind="decode") -> KernelOp:
    return KernelOp(next(_OP_COUNTER), stream_id, kind, shape, arrival_t,
                    deadline_t, seq_index, tag, model_id,
                    op_kind=op_kind)


# ---------------------------------------------------------------------------
# GEMM population extraction (Fig. 7)
# ---------------------------------------------------------------------------

def gemm_population(cfg: ModelConfig, batch: int, mode: str = "decode"
                    ) -> List[Tuple[str, GemmShape]]:
    """The per-step GEMM problems of one architecture.

    mode="decode": m = batch (token-parallel GEMV-like problems).
    mode="prefill": m = batch * seq would be supplied by caller via ``batch``.
    Returns (tag, GemmShape) pairs, one entry per layer occurrence collapsed
    to a single representative (the population repeats ``num_layers`` times).
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    out: List[Tuple[str, GemmShape]] = []
    m = batch

    def g(tag: str, n: int, k: int):
        out.append((tag, GemmShape(m=m, n=n, k=k)))

    if cfg.arch_type == "ssm":
        s = cfg.ssm
        d_inner = s.expand * d
        g("ssm_in_proj", 2 * d_inner + 2 * s.d_state + s.num_heads(d), d)
        g("ssm_out_proj", d, d_inner)
    else:
        g("attn_q", cfg.num_heads * hd, d)
        g("attn_kv", 2 * cfg.num_kv_heads * hd, d)
        g("attn_o", d, cfg.num_heads * hd)
        if cfg.has_moe:
            # per-expert problems: tokens split across experts
            per_expert_m = max(1, (m * cfg.moe.top_k) // cfg.moe.num_experts)
            for tag, n, k in [("expert_gate", cfg.d_ff, d),
                              ("expert_up", cfg.d_ff, d),
                              ("expert_down", d, cfg.d_ff)]:
                out.append((tag, GemmShape(m=per_expert_m, n=n, k=k)))
            g("router", cfg.moe.num_experts, d)
        elif cfg.arch_type == "hybrid":
            s = cfg.ssm
            d_inner = s.expand * d
            g("ssm_in_proj", 2 * d_inner + 2 * s.d_state + s.num_heads(d), d)
            g("ssm_out_proj", d, d_inner)
            g("ffn_gate", cfg.d_ff, d)
            g("ffn_up", cfg.d_ff, d)
            g("ffn_down", d, cfg.d_ff)
        else:
            g("ffn_gate", cfg.d_ff, d)
            g("ffn_up", cfg.d_ff, d)
            g("ffn_down", d, cfg.d_ff)
    g("unembed", cfg.padded_vocab, d)
    return out


def stream_program(cfg: ModelConfig, stream_id: int, batch: int, *,
                   arrival_t: float = 0.0, slo_s: float = float("inf"),
                   mode: str = "decode") -> List[KernelOp]:
    """Expand one request into its full per-layer op stream (program order)."""
    ops: List[KernelOp] = []
    seq = 0
    layer_ops = gemm_population(cfg, batch, mode)
    body = [t for t in layer_ops if t[0] != "unembed"]
    for _layer in range(cfg.num_layers):
        for tag, shape in body:
            kind = op_aspect(shape.m)
            ops.append(make_op(stream_id, kind, shape, arrival_t=arrival_t,
                               deadline_t=arrival_t + slo_s, seq_index=seq,
                               tag=tag, model_id=cfg.name))
            seq += 1
    tag, shape = layer_ops[-1]
    ops.append(make_op(stream_id, "gemm", shape, arrival_t=arrival_t,
                       deadline_t=arrival_t + slo_s, seq_index=seq, tag=tag,
                       model_id=cfg.name))
    return ops


def zoo_population(configs: Sequence[ModelConfig], batch: int = 1
                   ) -> List[Tuple[str, str, GemmShape]]:
    """(arch, tag, shape) for the whole zoo — the Fig. 7 scatter."""
    rows = []
    for cfg in configs:
        for tag, shape in gemm_population(cfg, batch):
            rows.append((cfg.name, tag, shape))
    return rows
