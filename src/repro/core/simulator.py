"""Event-driven execution simulator for the three multiplexing regimes.

Reproduces the paper's comparisons on one modeled device:
  * time-only multiplexing (§4.1, Fig. 4)  — serialized kernels + context
    switch flushes;
  * space-only multiplexing (§4.2, Fig. 5) — concurrent uncoordinated
    streams with contention (progress-based simulation: active kernels share
    units/bandwidth, so their service rates change as tenants come and go —
    this is exactly the source of the paper's unpredictability);
  * OoO VLIW JIT (§5) — our scheduler: coalesced superkernels dispatched
    serially (on TPU the superkernel IS the spatial multiplexing).

The simulator is policy-faithful, not cycle-accurate: kernel latencies come
from the calibrated roofline cost model (core/costmodel.py).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.coalescer import Coalescer
from repro.core.costmodel import CostModel
from repro.core.kernelspec import KernelOp, stream_program
from repro.core.scheduler import OoOScheduler, SchedulerConfig


@dataclasses.dataclass
class Request:
    req_id: int
    stream_id: int
    arrival_t: float
    slo_s: float
    ops: List[KernelOp]

    @property
    def deadline_t(self) -> float:
        return self.arrival_t + self.slo_s


@dataclasses.dataclass
class SimResult:
    name: str
    latencies: Dict[int, float]              # req_id -> completion latency
    makespan: float
    useful_flops: float
    peak_flops: float
    slo_misses: int
    num_requests: int

    @property
    def mean_latency(self) -> float:
        v = list(self.latencies.values())
        return sum(v) / len(v) if v else 0.0

    def p(self, q: float) -> float:
        v = sorted(self.latencies.values())
        if not v:
            return 0.0
        return v[min(int(q * len(v)), len(v) - 1)]

    @property
    def throughput_rps(self) -> float:
        return self.num_requests / self.makespan if self.makespan else 0.0

    @property
    def utilization(self) -> float:
        return self.useful_flops / (self.makespan * self.peak_flops) \
            if self.makespan else 0.0

    @property
    def slo_attainment(self) -> float:
        return 1.0 - self.slo_misses / max(self.num_requests, 1)


def make_requests(streams: Sequence[Tuple[ModelConfig, float, Sequence[float]]],
                  batch: int = 1) -> List[Request]:
    """streams: (config, slo_s, arrival_times) per tenant."""
    reqs: List[Request] = []
    rid = 0
    for sid, (cfg, slo, arrivals) in enumerate(streams):
        for t in arrivals:
            ops = stream_program(cfg, sid, batch, arrival_t=t, slo_s=slo)
            reqs.append(Request(rid, sid, t, slo, ops))
            rid += 1
    return sorted(reqs, key=lambda r: r.arrival_t)


def _finalize(name: str, cost: CostModel, reqs: Sequence[Request],
              done_t: Dict[int, float], makespan: float) -> SimResult:
    lat = {r.req_id: done_t[r.req_id] - r.arrival_t for r in reqs}
    misses = sum(1 for r in reqs if done_t[r.req_id] > r.deadline_t)
    useful = sum(op.shape.flops for r in reqs for op in r.ops)
    return SimResult(name, lat, makespan, useful, cost.device.peak_flops,
                     misses, len(reqs))


# ---------------------------------------------------------------------------
# time-only multiplexing: FIFO serialized kernels (paper §4.1)
# ---------------------------------------------------------------------------

def simulate_time_mux(reqs: Sequence[Request], cost: CostModel) -> SimResult:
    switch_s = 10e-6
    now = 0.0
    done_t: Dict[int, float] = {}
    last_stream: Optional[int] = None
    # round-robin between streams op-by-op (the GPU context scheduler
    # interleaves contexts; each switch flushes the pipeline)
    queues: Dict[int, List[Request]] = {}
    for r in reqs:
        queues.setdefault(r.stream_id, []).append(r)
    progress: Dict[int, int] = {}
    active: List[Request] = []
    pending = sorted(reqs, key=lambda r: r.arrival_t)
    pi = 0
    while len(done_t) < len(reqs):
        while pi < len(pending) and pending[pi].arrival_t <= now:
            active.append(pending[pi]); pi += 1
        if not active:
            now = pending[pi].arrival_t
            continue
        # round-robin over active requests
        r = active.pop(0)
        i = progress.get(r.req_id, 0)
        if last_stream is not None and last_stream != r.stream_id:
            now += switch_s
        op = r.ops[i]
        now += cost.gemm_time(op.shape)
        last_stream = r.stream_id
        progress[r.req_id] = i + 1
        if i + 1 == len(r.ops):
            done_t[r.req_id] = now
        else:
            active.append(r)
    return _finalize("time-mux", cost, reqs, done_t, now)


# ---------------------------------------------------------------------------
# space-only multiplexing: concurrent streams with contention (paper §4.2)
# ---------------------------------------------------------------------------

def simulate_space_mux(reqs: Sequence[Request], cost: CostModel) -> SimResult:
    """Progress-based simulation. Each stream runs its op sequence on its own
    'virtual context'; at any instant K active contexts share the device and
    each active op's service rate is its isolated rate divided by the
    contention factor from the cost model."""
    per_stream: Dict[int, List[Request]] = {}
    for r in reqs:
        per_stream.setdefault(r.stream_id, []).append(r)
    for q in per_stream.values():
        q.sort(key=lambda r: r.arrival_t)

    # context state: (request, op index, remaining isolated-seconds)
    ctx: Dict[int, Optional[Tuple[Request, int, float]]] = {
        s: None for s in per_stream}
    done_t: Dict[int, float] = {}
    now = 0.0
    pending = sorted(reqs, key=lambda r: r.arrival_t)
    pi = 0

    def load_next(sid: int) -> None:
        q = per_stream[sid]
        while q and q[0].req_id in done_t:
            q.pop(0)
        if q and q[0].arrival_t <= now:
            r = q[0]
            ctx[sid] = (r, 0, cost.gemm_time(r.ops[0].shape, co_tenants=1))

    while len(done_t) < len(reqs):
        while pi < len(pending) and pending[pi].arrival_t <= now:
            pi += 1
        for sid in ctx:
            if ctx[sid] is None:
                load_next(sid)
        active = [s for s, c in ctx.items() if c is not None]
        if not active:
            if pi < len(pending):
                now = pending[pi].arrival_t
                continue
            break
        K = len(active)
        slowdown = K * (1.25 if K > 1 else 1.0)  # shared units + interference
        # block-scheduler anomalies (paper Fig. 5): deterministic per-stream
        # jitter, amplified at odd tenant counts where SM partitioning is
        # uneven. hash-based so runs are reproducible.
        jit_amp = cost.device.spatial_jitter * (1.5 if K % 2 == 1 and K > 1
                                                else 1.0)
        def stream_slow(s: int) -> float:
            if K <= 1:
                return slowdown
            h = ((s * 2654435761 + K * 40503) % 1000) / 1000.0
            return slowdown * (1.0 + jit_amp * h)

        # next completion among active ops, or next arrival
        t_next = min(ctx[s][2] * stream_slow(s) for s in active)  # type: ignore[index]
        if pi < len(pending):
            t_next = min(t_next, pending[pi].arrival_t - now)
        t_next = max(t_next, 0.0)
        for s in active:
            r, i, rem = ctx[s]  # type: ignore[misc]
            rem -= t_next / stream_slow(s)
            if rem <= 1e-15:
                if i + 1 == len(r.ops):
                    done_t[r.req_id] = now + t_next
                    ctx[s] = None
                else:
                    ctx[s] = (r, i + 1,
                              cost.gemm_time(r.ops[i + 1].shape, co_tenants=1))
            else:
                ctx[s] = (r, i, rem)
        now += t_next
    return _finalize("space-mux", cost, reqs, done_t, now)


# ---------------------------------------------------------------------------
# the OoO VLIW JIT (paper §5)
# ---------------------------------------------------------------------------

def simulate_vliw(reqs: Sequence[Request], cost: CostModel,
                  sched_cfg: SchedulerConfig = SchedulerConfig(),
                  max_group: int = 64) -> SimResult:
    coal = Coalescer(cost, max_group=max_group)
    sched = OoOScheduler(cost, coal, sched_cfg)
    done_t: Dict[int, float] = {}
    now = 0.0
    pending = sorted(reqs, key=lambda r: r.arrival_t)
    pi = 0
    # per-request: ops issue in order; next issuable index
    next_idx: Dict[int, int] = {r.req_id: 0 for r in reqs}
    inflight: Dict[int, Request] = {}

    def admit(r: Request) -> None:
        sched.annotate_stream(r.ops)
        sched.push([r.ops[0]])
        inflight[r.req_id] = r

    by_op: Dict[int, Request] = {}
    for r in reqs:
        for op in r.ops:
            by_op[op.op_id] = r

    while len(done_t) < len(reqs):
        while pi < len(pending) and pending[pi].arrival_t <= now:
            admit(pending[pi]); pi += 1
        sched.next_arrival_t = pending[pi].arrival_t if pi < len(pending) \
            else math.inf
        d = sched.decide(now)
        if d.kind == "idle":
            if pi < len(pending):
                now = pending[pi].arrival_t
                continue
            break
        if d.kind == "wait":
            now = max(d.wait_until, now + 1e-9)
            continue
        plan = d.plan
        now += plan.est_time_s
        # completion: release each op's successor in its request
        for op in plan.ops:
            r = by_op[op.op_id]
            i = next_idx[r.req_id] + 1
            next_idx[r.req_id] = i
            if i == len(r.ops):
                done_t[r.req_id] = now
            else:
                nxt = r.ops[i]
                nxt.arrival_t = now
                sched.push([nxt])
    return _finalize("vliw", cost, reqs, done_t, now)


POLICIES = {
    "time": simulate_time_mux,
    "space": simulate_space_mux,
    "vliw": simulate_vliw,
}
