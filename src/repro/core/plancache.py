"""Persistent compiled-plan cache for the OoO JIT hot path.

The paper's premise (§5, after Jain et al., *Dynamic Space-Time Scheduling
for GPU Inference*) is that late-binding scheduling only wins if the
scheduler itself stays off the critical path. Our runtime used to pay an
early-binding tax on every tick: ``build_dense_decode_program`` re-derived
the full stage list for every decode step of every tenant, and the
coalescer re-derived block plans per dispatch. This module is the shared
memoization substrate that retires that tax:

  * **program templates** — ``core/jit.py`` caches compiled
    ``ProgramTemplate``s (stage list + glue closures + weight keys) keyed by
    ``(model identity, active batch m, dtype, cache geometry)`` and rebinds
    only the per-step environment (tokens, KV cache refs, deadlines) via
    ``ProgramTemplate.bind``;
  * **block plans** — the ``Coalescer`` memoizes the superkernel
    grid/block choice + modeled latency per coalesced group signature
    (ordered shape tuple, shared-operand flag).

Invalidation semantics (the cache must never serve a stale plan):

  * **identity guard** — every entry may carry a ``guard`` object (for
    program templates: the ``(model, params)`` pair whose closures the
    template baked in). A lookup whose guard is not the *same object*
    (tuples match element-wise by ``is``) invalidates the entry and
    rebuilds: a weight or model hot-swap therefore can never serve stale
    closures. Guard references are strong on purpose — they pin the old
    objects alive while the entry exists, so a recycled ``id()`` can never
    alias two distinct models or param trees.
  * **group tracking** — a caller may tag lookups with a ``group`` (e.g.
    the tenant name). When the group's key changes — a tenant's active
    batch m changed, its cache was re-geometried — the previous key is
    invalidated immediately (unless another group still uses it) instead
    of lingering until LRU pressure.
  * **LRU capacity bound** — beyond ``capacity`` entries the least
    recently used entry is evicted (counted separately from semantic
    invalidations). ``capacity=0`` disables storage entirely: every
    lookup is a miss and nothing is retained (the "uncached" baseline in
    tests and benchmarks).
  * **LRU byte budget** — with ``byte_capacity`` set, entries also evict
    LRU-first while ``sum(value.nbytes)`` exceeds the budget (values
    without ``nbytes`` count 0, so only array-valued caches — e.g. the
    dispatch executor's packed weights, incl. MoE stacked expert packs —
    are byte-constrained). A value bigger than the whole budget is passed
    through uncached rather than wiping every resident entry.

This module is dependency-free (stdlib only) so every layer of the stack —
coalescer, JIT, serving engine — can import it without cycles.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional, Tuple


@dataclasses.dataclass
class PlanCacheStats:
    """Counters for one plan cache. Supports ``+``/``-`` so deltas can be
    folded through ``JitStats.merge`` alongside the other run counters."""

    hits: int = 0
    misses: int = 0
    invalidations: int = 0     # guard mismatch / group key change / explicit
    evictions: int = 0         # LRU capacity pressure only

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0

    def copy(self) -> "PlanCacheStats":
        return dataclasses.replace(self)

    def _combine(self, other: "PlanCacheStats", sign: int) -> "PlanCacheStats":
        return PlanCacheStats(
            *(getattr(self, f.name) + sign * getattr(other, f.name)
              for f in dataclasses.fields(self)))

    def __add__(self, other: "PlanCacheStats") -> "PlanCacheStats":
        return self._combine(other, +1)

    def __sub__(self, other: "PlanCacheStats") -> "PlanCacheStats":
        return self._combine(other, -1)


@dataclasses.dataclass
class _Entry:
    value: Any
    guard: Any = None


def _guard_matches(stored: Any, guard: Any) -> bool:
    """Identity match. A tuple guard matches element-wise by ``is`` so a
    caller can guard one entry on several live objects at once (e.g. the
    tenant's model AND params) — the stored tuple pins them all, so none of
    their ids can be recycled while the entry exists."""
    if isinstance(stored, tuple) and isinstance(guard, tuple) \
            and len(stored) == len(guard):
        return all(a is b for a, b in zip(stored, guard))
    return stored is guard


class PlanCache:
    """Capacity-bounded LRU cache with identity-guard and group invalidation.

    ``get_or_build(key, build)`` returns the cached value for ``key`` or
    builds, stores and returns a fresh one. See the module docstring for the
    ``guard`` / ``group`` / ``capacity`` semantics.
    """

    def __init__(self, capacity: int = 128,
                 byte_capacity: Optional[int] = None):
        assert capacity >= 0
        self.capacity = capacity
        # optional LRU budget over sum(value.nbytes): entry-count bounds
        # are meaningless when values are full packed weight copies (one
        # entry can be hundreds of MB at real model sizes). Values without
        # an ``nbytes`` (block plans, templates) count as 0 — the byte
        # budget only constrains array-valued caches.
        self.byte_capacity = byte_capacity
        self.bytes = 0
        self._entries: "OrderedDict[Hashable, _Entry]" = OrderedDict()
        self._group_key: Dict[Hashable, Hashable] = {}
        self.stats = PlanCacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        return list(self._entries)

    @staticmethod
    def _nbytes(entry: _Entry) -> int:
        return int(getattr(entry.value, "nbytes", 0))

    def _pop(self, key: Hashable) -> Optional[_Entry]:
        entry = self._entries.pop(key, None)
        if entry is not None:
            self.bytes -= self._nbytes(entry)
            self._forget_groups(key)
        return entry

    def _forget_groups(self, key: Hashable) -> None:
        """Drop group mappings whose target entry no longer exists —
        otherwise ``_group_key`` grows one tuple per group composition
        ever seen (the hot dispatch path feeds per-group tags), and dead
        mappings slow the key-change scan forever."""
        dead = [g for g, k in self._group_key.items() if k == key]
        for g in dead:
            del self._group_key[g]

    # ------------------------------------------------------------------
    def get_or_build(self, key: Hashable, build: Callable[[], Any], *,
                     guard: Any = None, group: Optional[Hashable] = None
                     ) -> Any:
        return self.get_or_build_flagged(key, build, guard=guard,
                                         group=group)[0]

    def get_or_build_flagged(self, key: Hashable, build: Callable[[], Any], *,
                             guard: Any = None,
                             group: Optional[Hashable] = None
                             ) -> "Tuple[Any, bool]":
        """``get_or_build`` that also reports whether the lookup HIT.

        Callers that account avoided work per access (e.g. the dispatch
        executor's bytes-not-copied counter) need the per-call outcome, not
        just the aggregate stats delta."""
        # capacity 0 stores nothing, so there are no entries for group
        # tracking to invalidate — recording mappings would only leak
        if group is not None and self.capacity == 0:
            group = None
        if group is not None:
            old = self._group_key.get(group)
            if old is not None and old != key:
                # the group's plan shape changed (e.g. batch-size change):
                # its previous entry can never be valid for it again. Only
                # drop it if no other group still resolves to it.
                if not any(k == old for g, k in self._group_key.items()
                           if g != group):
                    if self._pop(old) is not None:
                        self.stats.invalidations += 1
            self._group_key[group] = key
        entry = self._entries.get(key)
        if entry is not None:
            if guard is not None and not _guard_matches(entry.guard, guard):
                # identity guard tripped (weight hot-swap): stale plan
                self._pop(key)
                self.stats.invalidations += 1
                if group is not None:   # _pop swept the mapping set above
                    self._group_key[group] = key
            else:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return entry.value, True
        self.stats.misses += 1
        value = build()
        if self.capacity > 0:
            entry = _Entry(value, guard)
            if self.byte_capacity is not None \
                    and self._nbytes(entry) > self.byte_capacity:
                # an entry bigger than the WHOLE byte budget can never be
                # retained legally — storing it used to wipe every other
                # entry (each dropped for nothing, since the cache stayed
                # over budget anyway with the giant pinned as "newest").
                # Large MoE expert packs hit this: pass the value through
                # uncached instead, leaving unrelated entries intact.
                return value, False
            self._entries[key] = entry
            self._entries.move_to_end(key)
            self.bytes += self._nbytes(entry)
            while len(self._entries) > self.capacity or (
                    self.byte_capacity is not None
                    and self.bytes > self.byte_capacity
                    and len(self._entries) > 1):   # keep the newest entry
                k, dropped = self._entries.popitem(last=False)
                self.bytes -= self._nbytes(dropped)
                self._forget_groups(k)
                self.stats.evictions += 1
        return value, False

    # ------------------------------------------------------------------
    def peek(self, key: Hashable) -> Any:
        """Read an entry WITHOUT touching stats, LRU order or guards
        (``None`` if absent). For introspection only — bench summaries and
        lifecycle tests read tuned configs through this so observing a
        cache never perturbs the hit-rate acceptance criteria it gates."""
        entry = self._entries.get(key)
        return entry.value if entry is not None else None

    def invalidate(self, key: Hashable) -> bool:
        """Explicitly drop one entry; returns whether it existed."""
        if self._pop(key) is not None:
            self.stats.invalidations += 1
            return True
        return False

    def clear(self) -> None:
        """Drop everything (counted as invalidations)."""
        self.stats.invalidations += len(self._entries)
        self._entries.clear()
        self._group_key.clear()
        self.bytes = 0
