"""Analytical device/cost model used by the JIT scheduler, the autotuner and
the multiplexing simulator.

Per-kernel latency is a roofline estimate with *wave quantization*: a kernel
that produces fewer output tiles than the device has parallel units cannot
reach peak FLOP/s no matter its arithmetic intensity — this is precisely the
"utilization gap" of paper §3 (Fig. 3) and the physical origin of the
coalescing win (Fig. 6): packing G small problems into one superkernel
multiplies the tile count by ~G, filling the idle units.

Two device profiles are built in:
  * V100  — calibrated to the paper's hardware (15.7 TFLOPS fp32, 900 GB/s),
    used to reproduce the paper's own numbers;
  * TPUV5E — the deployment target (197 TFLOPS bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI), used for the TPU-native roofline in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    peak_flops: float          # FLOP/s at the serving dtype
    hbm_bw: float              # bytes/s
    num_units: int             # parallel execution units (SMs / MXU tiles)
    unit_tile: Tuple[int, int] # native output tile of one unit (m, n)
    vmem_bytes: int            # per-core fast memory (VMEM / L1+smem budget)
    launch_overhead_s: float   # fixed per-kernel dispatch cost
    ici_bw: float = 0.0        # bytes/s per link (TPU only)
    # per-hop interconnect latency for the collective terms (ring step /
    # all-to-all exchange). GPUs without a declared ici_bw fall back to a
    # PCIe/NVLink-ish fraction of HBM bandwidth (see CostModel._ici_bw).
    ici_latency_s: float = 1e-6
    # non-matrix-unit fallback rate (CUDA cores / TPU VPU): tiny-m problems
    # run here without MXU tile-padding losses
    vector_flops: float = 0.0
    # Calibrated spatial-multiplexing saturation: K concurrent uncoordinated
    # kernels achieve ~K^alpha aggregate speedup over serial (paper Fig. 4/6:
    # Hyper-Q reaches ~2.4x at 8 tenants on V100 => alpha ~ 0.38). Block
    # scheduling anomalies add jitter (Fig. 5), worse at odd tenant counts.
    spatial_alpha: float = 0.38
    spatial_jitter: float = 0.35
    # Co-tenancy coordination (Table 1): kernels whose combined per-wave
    # working set fits in shared cache (L2 on GPU) interleave without thrash
    # and approach alpha_coordinated concurrency scaling.
    l2_bytes: int = 6 * 1024 * 1024
    alpha_coordinated: float = 0.78


# The paper's testbed: NVIDIA V100 (Fig. 3 caption: 15.7 TFLOPS advertised).
V100 = Device(
    name="v100",
    peak_flops=15.7e12,
    hbm_bw=900e9,
    num_units=80,              # 80 SMs
    unit_tile=(32, 32),        # warp-level MMA granularity
    vmem_bytes=96 * 1024,      # unified smem/L1 per SM
    launch_overhead_s=5e-6,
    l2_bytes=6 * 1024 * 1024 + 512 * 1024,
    vector_flops=7.8e12,       # fp32 CUDA cores
)

# Deployment target: TPU v5e (assignment constants).
TPUV5E = Device(
    name="tpuv5e",
    peak_flops=197e12,         # bf16
    hbm_bw=819e9,
    num_units=8,               # MXU-equivalent parallel tiles per core-step
    unit_tile=(128, 128),
    vmem_bytes=16 * 1024 * 1024,
    launch_overhead_s=2e-6,
    ici_bw=50e9,
    vector_flops=4e12,         # VPU
)


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """One GEMM problem: C[m,n] += A[m,k] @ B[k,n].

    ``layers`` models a LAYER-STACKED operand (core/jit.py
    ``StackedGemmStage``): one op that executes the same (m, n, k) GEMM
    ``layers`` times sequentially inside a ``jax.lax.scan`` over a stacked
    B[L,k,n]. The per-wave tile geometry (``CostModel.tiles``) is unchanged
    — each scan step launches the same tile wave — while flops, bytes and
    latency all scale by L (critical path = L·wave, not a single GEMM).
    """
    m: int
    n: int
    k: int
    dtype_bytes: int = 2
    layers: int = 1

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k * self.layers

    @property
    def bytes(self) -> float:
        return self.dtype_bytes * self.layers * (
            self.m * self.k + self.k * self.n + self.m * self.n)


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Tiling configuration for one (super)kernel — the autotuner's knob."""
    bm: int = 128
    bn: int = 128
    bk: int = 512

    def vmem_usage(self, k: int, dtype_bytes: int = 2) -> int:
        bk = min(self.bk, k)
        return dtype_bytes * (self.bm * bk + bk * self.bn) + 4 * self.bm * self.bn


DEFAULT_BLOCK = BlockConfig()


class CostModel:
    """Roofline + wave-quantization latency estimates on one device."""

    def __init__(self, device: Device):
        self.device = device

    # ------------------------------------------------------------------
    def tiles(self, shape: GemmShape, block: BlockConfig = DEFAULT_BLOCK) -> int:
        return math.ceil(shape.m / block.bm) * math.ceil(shape.n / block.bn)

    def compute_efficiency(self, total_tiles: int,
                           block: BlockConfig = DEFAULT_BLOCK,
                           units: Optional[int] = None) -> float:
        """Fraction of peak reachable given the output-tile count.

        Wave quantization: ``waves = ceil(tiles/units)`` full device steps are
        needed; only ``tiles`` of ``waves*units`` tile-slots do work. A second
        factor penalizes blocks narrower than the native unit tile (MXU padding).
        ``units`` can be overridden to model co-tenancy (each tenant sees a
        fraction of the device's parallel units).
        """
        d = self.device
        units = units or d.num_units
        waves = math.ceil(total_tiles / units)
        quant = total_tiles / (waves * units)
        fill = min(1.0, (block.bm / d.unit_tile[0])) * min(
            1.0, (block.bn / d.unit_tile[1]))
        return quant * fill

    def gemm_bytes(self, shape: GemmShape,
                   block: BlockConfig = DEFAULT_BLOCK) -> float:
        """HBM traffic with k-blocked tiling re-reads.

        Each output tile accumulates over k: the A panel is re-read once per
        n-tile column and the B panel once per m-tile row. Larger tiles =
        less re-read = the 'greedy' single-tenant optimum; smaller tiles =
        better load balance on a shared device = the 'collaborative' optimum
        (paper Table 1)."""
        n_tiles_m = math.ceil(shape.m / block.bm)
        n_tiles_n = math.ceil(shape.n / block.bn)
        a = shape.m * shape.k * n_tiles_n
        b = shape.k * shape.n * n_tiles_m
        c = shape.m * shape.n
        return shape.dtype_bytes * shape.layers * (a + b + c)

    # ------------------------------------------------------------------
    def gemm_time(self, shape: GemmShape,
                  block: BlockConfig = DEFAULT_BLOCK,
                  co_tenants: int = 1) -> float:
        """Latency of one GEMM kernel run with ``co_tenants`` concurrent
        kernels sharing the device (space multiplexing).

        With co-tenancy the kernel sees ~1/K of the units and of HBM
        bandwidth, plus an interference penalty (uncoordinated tile shapes
        thrash the memory system — paper §4.2 / Table 1's 'greedy kernels
        degrade each other')."""
        d = self.device
        units = max(1, d.num_units // co_tenants)
        interference = 1.0 if co_tenants == 1 else 1.25  # calibrated, §4.2
        share = units / d.num_units
        padded = 2.0 * math.ceil(shape.m / block.bm) * block.bm \
            * math.ceil(shape.n / block.bn) * block.bn * shape.k \
            * shape.layers
        t_compute = self._compute_time(shape.flops,
                                       self.tiles(shape, block), block,
                                       units=units, share=share,
                                       padded_flops=padded)
        t_memory = self.gemm_bytes(shape, block) \
            / (d.hbm_bw / co_tenants) * interference
        return max(t_compute, t_memory) + d.launch_overhead_s

    def _compute_time(self, useful_flops: float, total_tiles: int,
                      block: BlockConfig, units: Optional[int] = None,
                      share: float = 1.0,
                      padded_flops: Optional[float] = None) -> float:
        """Best of the matrix-unit path (tile-padded, fill-penalized) and the
        vector-unit fallback (no tile structure, wave-quantized only)."""
        d = self.device
        units = units or d.num_units
        eff = self.compute_efficiency(total_tiles, block, units=units)
        t_mxu = (padded_flops or useful_flops) \
            / (d.peak_flops * share * max(eff, 1e-6))
        if d.vector_flops <= 0:
            return t_mxu
        waves = math.ceil(total_tiles / units)
        quant = total_tiles / (waves * units)
        t_vec = useful_flops / (d.vector_flops * share * max(quant, 1e-6))
        return min(t_mxu, t_vec)

    # ------------------------------------------------------------------
    def coalesced_time(self, shapes: Sequence[GemmShape],
                       block: BlockConfig = DEFAULT_BLOCK,
                       shared_operand: bool = False) -> float:
        """Latency of one superkernel executing all ``shapes`` at once.

        Tiles add up (this is the whole point: the union fills the device).
        Memory traffic is the padded union; ``shared_operand=True`` models
        same-weight coalescing (multiple streams of the same model — the
        GEMV/RNN case §5.3) where the B matrix is loaded once.
        """
        if not shapes:
            return 0.0
        d = self.device
        if shared_operand:
            # same weights (same model+layer across streams): the problems
            # concatenate along m into ONE GEMM — B is loaded once.
            cat = GemmShape(m=sum(s.m for s in shapes),
                            n=max(s.n for s in shapes),
                            k=max(s.k for s in shapes),
                            dtype_bytes=shapes[0].dtype_bytes,
                            layers=max(s.layers for s in shapes))
            total_tiles = self.tiles(cat, block)
            padded = 2.0 * math.ceil(cat.m / block.bm) * block.bm \
                * math.ceil(cat.n / block.bn) * block.bn * cat.k \
                * cat.layers
            useful = sum(s.flops for s in shapes)
            io = self.gemm_bytes(cat, block)
        else:
            total_tiles = sum(self.tiles(s, block) for s in shapes)
            # padded flops: every problem is rounded up to tile multiples
            padded = sum(
                2.0 * math.ceil(s.m / block.bm) * block.bm
                * math.ceil(s.n / block.bn) * block.bn * s.k * s.layers
                for s in shapes)
            useful = sum(s.flops for s in shapes)
            io = sum(self.gemm_bytes(s, block) for s in shapes)
        t_compute = self._compute_time(useful, total_tiles, block,
                                       padded_flops=padded)
        t_memory = io / d.hbm_bw
        return max(t_compute, t_memory) + d.launch_overhead_s

    # ------------------------------------------------------------------
    def time_multiplexed(self, shapes: Sequence[GemmShape],
                         block: BlockConfig = DEFAULT_BLOCK) -> float:
        """Serial execution (paper §4.1) + context-switch flush overhead."""
        switch = 10e-6  # pipeline flush between contexts (§4.1)
        return sum(self.gemm_time(s, block) for s in shapes) \
            + switch * max(len(shapes) - 1, 0)

    def space_multiplexed(self, shapes: Sequence[GemmShape],
                          block: BlockConfig = DEFAULT_BLOCK) -> float:
        """Concurrent uncoordinated execution (paper §4.2).

        Two regimes bound the makespan:
          * saturation — K uncoordinated kernels only reach ~K^alpha aggregate
            speedup over serial (block-scheduler interleaving, L2/DRAM thrash;
            calibrated to the paper's Hyper-Q measurements);
          * partition  — no tenant finishes faster than it would on its 1/K
            device share (per-block-config, used by the Table 1 autotuner).
        """
        K = len(shapes)
        if K == 0:
            return 0.0
        d = self.device
        serial = sum(self.gemm_time(s, block) for s in shapes)
        # combined per-wave working set across resident blocks
        blk_bytes = shapes[0].dtype_bytes * (
            block.bm * min(block.bk, max(s.k for s in shapes))
            + min(block.bk, max(s.k for s in shapes)) * block.bn) \
            + 4 * block.bm * block.bn
        coordinated = d.num_units * blk_bytes <= d.l2_bytes
        if coordinated:
            return serial / (K ** d.alpha_coordinated)
        saturated = serial / (K ** d.spatial_alpha)
        partitioned = max(self.gemm_time(s, block, co_tenants=K)
                          for s in shapes)
        return max(saturated, partitioned)

    # ------------------------------------------------------------------
    # cross-device collectives (multi-device mesh serving)
    # ------------------------------------------------------------------
    def _ici_bw(self) -> float:
        """Effective per-link interconnect bandwidth. Devices that declare
        ``ici_bw`` (TPU ICI) use it; GPU profiles without one fall back to
        hbm_bw/8 — a PCIe4/NVLink-class fraction, so collective charges
        stay finite and conservative rather than silently zero."""
        d = self.device
        return d.ici_bw if d.ici_bw > 0 else d.hbm_bw / 8.0

    def ring_allreduce_time(self, bytes_per_device: float,
                            n_devices: int) -> float:
        """Bandwidth-latency model of a ring all-reduce over ``n_devices``:
        reduce-scatter + all-gather each move (n-1)/n of the buffer per
        device and take (n-1) ring steps — the TP psum charge."""
        if n_devices <= 1 or bytes_per_device <= 0:
            return 0.0
        d = self.device
        steps = 2 * (n_devices - 1)
        moved = 2.0 * (n_devices - 1) / n_devices * bytes_per_device
        return moved / self._ici_bw() + steps * d.ici_latency_s

    def all_to_all_time(self, bytes_per_device: float,
                        n_devices: int) -> float:
        """Bandwidth-latency model of an all-to-all over ``n_devices``:
        each device keeps 1/n of its buffer and exchanges the rest in
        (n-1) pairwise steps — the MoE expert dispatch/combine charge."""
        if n_devices <= 1 or bytes_per_device <= 0:
            return 0.0
        d = self.device
        moved = (n_devices - 1) / n_devices * bytes_per_device
        return moved / self._ici_bw() + (n_devices - 1) * d.ici_latency_s

    # ------------------------------------------------------------------
    def achieved_tflops(self, shapes: Sequence[GemmShape], t: float) -> float:
        return sum(s.flops for s in shapes) / t / 1e12

    def utilization(self, shapes: Sequence[GemmShape], t: float) -> float:
        return sum(s.flops for s in shapes) / (t * self.device.peak_flops)
