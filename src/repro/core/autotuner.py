"""Co-tenancy autotuning (paper §5.3, Table 1) — offline AND live.

GPU programs have many tunable parameters; kernels are usually tuned
assuming they own the whole device ("greedy"). The paper's point: when
kernels are dispatched concurrently, a *collaboratively* tuned configuration
— smaller working set, better load balance on a shared device — achieves
higher aggregate throughput despite a modest isolated-run regression.

On TPU the tunable is the Pallas ``BlockSpec`` tile geometry (bm, bn, bk)
under the VMEM budget; the two objectives are:

  * greedy        — minimize isolated latency (full device, sole tenant);
  * collaborative — minimize the superkernel latency of G co-resident
    problems (or, for space-sim comparisons, the K-tenant makespan).

The search space is small and the objective is the analytic cost model, so
exhaustive search is exact and fast; ``tests/test_autotuner.py`` cross-
validates tuned tile choices against interpret-mode Pallas runs.

Offline vs live API
-------------------
``Autotuner`` is the OFFLINE face: given shapes ahead of time it produces
``TuneResult``s (Table 1 rows) or an AOT block table
(``tune_table``) that a ``Coalescer`` can be seeded with. It knows nothing
about dispatch order or caching — every call searches.

``LiveTuner`` is the LIVE face, sitting on the JIT dispatch hot path: the
``Coalescer`` consults it on every ``plan()`` with the actual coalesced
group (the G co-resident problems of THIS tick), and it exhaustively tunes
(bm, bn, bk) for the group's full shape signature under the chosen
objective — collaborative by default, VMEM-bounded via
``Autotuner.candidates`` — memoizing the ``LiveTuneResult`` per
(device, signature) key in a ``PlanCache`` (``VLIWJit.tune_cache``, living
beside the block-plan memo). Steady-state ticks therefore pay one cache
hit, zero search: the tune-cache hit rate is a gated serving acceptance
criterion (benchmarks/compiled_autotune_bench.py). Group churn (a tenant
joining or leaving changes the signature) re-tunes ONCE for the new
signature; the previous signature's entry is untouched, so a group that
churns back — or other groups mid-churn — keep being served their already-
tuned config. Tuning keys carry no params identity (shapes only), so a
weight hot-swap leaves every tuned config intact.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.clustering import exact_key
from repro.core.costmodel import BlockConfig, CostModel, GemmShape
from repro.core.plancache import PlanCache


# MXU-aligned candidate tiles (bm may drop low for decode GEMV problems)
_BM = (8, 16, 32, 64, 128, 256, 512)
_BN = (128, 256, 512)
_BK = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass
class TuneResult:
    shape: GemmShape
    greedy: BlockConfig
    collaborative: BlockConfig
    greedy_isolated_s: float
    collab_isolated_s: float
    greedy_multiplexed_s: float
    collab_multiplexed_s: float
    co_tenants: int

    @property
    def multiplexed_speedup(self) -> float:
        """Collaborative vs greedy under co-tenancy (paper: 1.25×)."""
        return self.greedy_multiplexed_s / self.collab_multiplexed_s

    @property
    def isolated_regression(self) -> float:
        """Isolated slowdown paid by the collaborative kernel (paper: ~20%)."""
        return self.collab_isolated_s / self.greedy_isolated_s - 1.0


class Autotuner:
    def __init__(self, cost: CostModel):
        self.cost = cost

    def candidates(self, shape: GemmShape) -> List[BlockConfig]:
        out = []
        for bm, bn, bk in itertools.product(_BM, _BN, _BK):
            if bm > max(shape.m, 8) * 2 or bn > shape.n * 2 or bk > shape.k * 2:
                continue
            b = BlockConfig(bm, bn, bk)
            if b.vmem_usage(shape.k, shape.dtype_bytes) \
                    <= self.cost.device.vmem_bytes:
                out.append(b)
        return out or [BlockConfig()]

    # ------------------------------------------------------------------
    def tune_greedy(self, shape: GemmShape) -> BlockConfig:
        return min(self.candidates(shape),
                   key=lambda b: self.cost.gemm_time(shape, b))

    def tune_collaborative(self, shape: GemmShape, co_tenants: int
                           ) -> BlockConfig:
        """Minimize the K-tenant concurrent-dispatch makespan (the paper's
        Table 1 setting: retuned kernels dispatched concurrently via MPS)."""
        group = [shape] * co_tenants
        return min(self.candidates(shape),
                   key=lambda b: self.cost.space_multiplexed(group, b))

    def tune_for_coalescing(self, shape: GemmShape, group_size: int
                            ) -> BlockConfig:
        """Best tile for the JIT's coalesced superkernel of G problems."""
        group = [shape] * group_size
        return min(self.candidates(shape),
                   key=lambda b: self.cost.coalesced_time(group, b))

    def tune_group(self, shapes: Sequence[GemmShape],
                   objective: str = "collaborative", *,
                   shared_operand: bool = False) -> BlockConfig:
        """Tune one HETEROGENEOUS coalesced group (the live-path objective).

        Candidates come from the group's envelope shape (max extents —
        the superkernel pads every member to it), VMEM-bounded as always.

          * collaborative — minimize the one-superkernel latency of the G
            co-resident problems (``CostModel.coalesced_time``), padding
            waste and all: the group IS the co-tenancy;
          * greedy — minimize the envelope problem's ISOLATED latency, as
            if the largest member owned the device alone. This is the
            ablation the Table 1 claim is measured against: a greedy tile
            maximizes per-tile reuse but under-fills the device and
            inflates the small members' padding when the group dispatches
            as one superkernel.
        """
        assert objective in ("collaborative", "greedy"), objective
        shapes = list(shapes)
        env = GemmShape(m=max(s.m for s in shapes),
                        n=max(s.n for s in shapes),
                        k=max(s.k for s in shapes),
                        dtype_bytes=max(s.dtype_bytes for s in shapes),
                        layers=max(s.layers for s in shapes))
        cands = self.candidates(env)
        if objective == "greedy":
            return min(cands, key=lambda b: self.cost.gemm_time(env, b))
        return min(cands, key=lambda b: self.cost.coalesced_time(
            shapes, b, shared_operand=shared_operand))

    # ------------------------------------------------------------------
    def tune(self, shape: GemmShape, co_tenants: int = 2) -> TuneResult:
        g = self.tune_greedy(shape)
        c = self.tune_collaborative(shape, co_tenants)
        group = [shape] * co_tenants
        return TuneResult(
            shape=shape, greedy=g, collaborative=c,
            greedy_isolated_s=self.cost.gemm_time(shape, g),
            collab_isolated_s=self.cost.gemm_time(shape, c),
            # multiplexed = each tenant dispatches its own kernel with its
            # tuned config, space-shared (the paper's Table 1 setting)
            greedy_multiplexed_s=self.cost.space_multiplexed(group, g),
            collab_multiplexed_s=self.cost.space_multiplexed(group, c),
            co_tenants=co_tenants,
        )

    # ------------------------------------------------------------------
    def tune_table(self, shapes: Sequence[GemmShape], co_tenants: int = 4
                   ) -> Dict[Tuple, BlockConfig]:
        """AOT-tuned block table keyed like the coalescer expects."""
        table: Dict[Tuple, BlockConfig] = {}
        for s in shapes:
            table[exact_key(s)] = self.tune_for_coalescing(s, co_tenants)
        return table


# ---------------------------------------------------------------------------
# live tuning (the JIT dispatch hot path)
# ---------------------------------------------------------------------------

def group_signature(shapes: Sequence[GemmShape]) -> Tuple:
    """Params-free identity of a coalesced group: the ordered full shape
    tuple — the same signature the coalescer's block-plan memo keys on, so
    'group churn' means exactly one thing across both caches."""
    return tuple((s.m, s.n, s.k, s.dtype_bytes, s.layers) for s in shapes)


@dataclasses.dataclass
class LiveTuneResult:
    """One live tuning decision, cached per (device, group signature)."""
    signature: Tuple
    objective: str               # "collaborative" | "greedy"
    shared_operand: bool
    block: BlockConfig
    modeled_group_s: float       # objective value at ``block``
    candidates: int              # search-space size actually evaluated


class LiveTuner:
    """Exhaustive per-group (bm, bn, bk) tuning on the live dispatch path.

    See the module docstring ("Offline vs live API"). One instance serves
    one device's coalescer; a mesh shares ONE ``cache`` (the JIT-owned
    ``tune_cache``) across per-device tuners, device-disambiguated by the
    ``device_id`` baked into every key — heterogeneous device profiles
    must never serve each other's tuned tiles.
    """

    def __init__(self, cost: CostModel, cache: Optional[PlanCache] = None,
                 *, objective: str = "collaborative", device_id: int = 0):
        assert objective in ("collaborative", "greedy"), objective
        self.autotuner = Autotuner(cost)
        self.cost = cost
        self.objective = objective
        self.cache = cache if cache is not None else PlanCache(256)
        self.device_id = device_id
        # reporting mirror (bench JSON summaries): tuned block per key for
        # every signature THIS tuner actually tuned. Not a cache — never
        # read on the hot path, survives nothing the PlanCache doesn't.
        self.results: Dict[Tuple, LiveTuneResult] = {}

    # ------------------------------------------------------------------
    def key_for(self, shapes: Sequence[GemmShape], *,
                shared_operand: bool = False) -> Tuple:
        return ("tune", self.device_id, self.objective,
                group_signature(shapes), shared_operand)

    def tune(self, shapes: Sequence[GemmShape], *,
             shared_operand: bool = False) -> BlockConfig:
        """Tuned block for this group signature — cached; searches only on
        the first sighting of a signature (or after churn invented a new
        one). The PlanCache orders this correctly under churn: a NEW
        signature builds its own entry while every existing entry — the
        'previous config' of groups mid-churn — keeps being served."""
        shapes = list(shapes)
        key = self.key_for(shapes, shared_operand=shared_operand)

        def build() -> LiveTuneResult:
            block = self.autotuner.tune_group(
                shapes, self.objective, shared_operand=shared_operand)
            env = GemmShape(m=max(s.m for s in shapes),
                            n=max(s.n for s in shapes),
                            k=max(s.k for s in shapes),
                            dtype_bytes=max(s.dtype_bytes for s in shapes),
                            layers=max(s.layers for s in shapes))
            modeled = self.cost.gemm_time(env, block) \
                if self.objective == "greedy" else \
                self.cost.coalesced_time(shapes, block,
                                         shared_operand=shared_operand)
            res = LiveTuneResult(
                signature=group_signature(shapes), objective=self.objective,
                shared_operand=shared_operand, block=block,
                modeled_group_s=modeled,
                candidates=len(self.autotuner.candidates(env)))
            self.results[key] = res
            return res

        return self.cache.get_or_build(key, build).block
