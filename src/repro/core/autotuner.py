"""Ahead-of-time co-tenancy autotuning (paper §5.3, Table 1).

GPU programs have many tunable parameters; kernels are usually tuned
assuming they own the whole device ("greedy"). The paper's point: when
kernels are dispatched concurrently, a *collaboratively* tuned configuration
— smaller working set, better load balance on a shared device — achieves
higher aggregate throughput despite a modest isolated-run regression.

On TPU the tunable is the Pallas ``BlockSpec`` tile geometry (bm, bn, bk)
under the VMEM budget; the two objectives are:

  * greedy        — minimize isolated latency (full device, sole tenant);
  * collaborative — minimize the superkernel latency of G co-resident
    problems (or, for space-sim comparisons, the K-tenant makespan).

The search space is small and the objective is the analytic cost model, so
exhaustive search is exact and fast; ``tests/test_autotuner.py`` cross-
validates tuned tile choices against interpret-mode Pallas runs.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.clustering import exact_key
from repro.core.costmodel import BlockConfig, CostModel, GemmShape


# MXU-aligned candidate tiles (bm may drop low for decode GEMV problems)
_BM = (8, 16, 32, 64, 128, 256, 512)
_BN = (128, 256, 512)
_BK = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass
class TuneResult:
    shape: GemmShape
    greedy: BlockConfig
    collaborative: BlockConfig
    greedy_isolated_s: float
    collab_isolated_s: float
    greedy_multiplexed_s: float
    collab_multiplexed_s: float
    co_tenants: int

    @property
    def multiplexed_speedup(self) -> float:
        """Collaborative vs greedy under co-tenancy (paper: 1.25×)."""
        return self.greedy_multiplexed_s / self.collab_multiplexed_s

    @property
    def isolated_regression(self) -> float:
        """Isolated slowdown paid by the collaborative kernel (paper: ~20%)."""
        return self.collab_isolated_s / self.greedy_isolated_s - 1.0


class Autotuner:
    def __init__(self, cost: CostModel):
        self.cost = cost

    def candidates(self, shape: GemmShape) -> List[BlockConfig]:
        out = []
        for bm, bn, bk in itertools.product(_BM, _BN, _BK):
            if bm > max(shape.m, 8) * 2 or bn > shape.n * 2 or bk > shape.k * 2:
                continue
            b = BlockConfig(bm, bn, bk)
            if b.vmem_usage(shape.k, shape.dtype_bytes) \
                    <= self.cost.device.vmem_bytes:
                out.append(b)
        return out or [BlockConfig()]

    # ------------------------------------------------------------------
    def tune_greedy(self, shape: GemmShape) -> BlockConfig:
        return min(self.candidates(shape),
                   key=lambda b: self.cost.gemm_time(shape, b))

    def tune_collaborative(self, shape: GemmShape, co_tenants: int
                           ) -> BlockConfig:
        """Minimize the K-tenant concurrent-dispatch makespan (the paper's
        Table 1 setting: retuned kernels dispatched concurrently via MPS)."""
        group = [shape] * co_tenants
        return min(self.candidates(shape),
                   key=lambda b: self.cost.space_multiplexed(group, b))

    def tune_for_coalescing(self, shape: GemmShape, group_size: int
                            ) -> BlockConfig:
        """Best tile for the JIT's coalesced superkernel of G problems."""
        group = [shape] * group_size
        return min(self.candidates(shape),
                   key=lambda b: self.cost.coalesced_time(group, b))

    # ------------------------------------------------------------------
    def tune(self, shape: GemmShape, co_tenants: int = 2) -> TuneResult:
        g = self.tune_greedy(shape)
        c = self.tune_collaborative(shape, co_tenants)
        group = [shape] * co_tenants
        return TuneResult(
            shape=shape, greedy=g, collaborative=c,
            greedy_isolated_s=self.cost.gemm_time(shape, g),
            collab_isolated_s=self.cost.gemm_time(shape, c),
            # multiplexed = each tenant dispatches its own kernel with its
            # tuned config, space-shared (the paper's Table 1 setting)
            greedy_multiplexed_s=self.cost.space_multiplexed(group, g),
            collab_multiplexed_s=self.cost.space_multiplexed(group, c),
            co_tenants=co_tenants,
        )

    # ------------------------------------------------------------------
    def tune_table(self, shapes: Sequence[GemmShape], co_tenants: int = 4
                   ) -> Dict[Tuple, BlockConfig]:
        """AOT-tuned block table keyed like the coalescer expects."""
        table: Dict[Tuple, BlockConfig] = {}
        for s in shapes:
            table[exact_key(s)] = self.tune_for_coalescing(s, co_tenants)
        return table
