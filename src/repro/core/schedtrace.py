"""Schedule traces and the hazard-violation taxonomy.

This module is the dependency-free data layer shared by the runtime
(``JitSession`` records a ``ScheduleTrace``; ``SuperkernelExecutor`` raises
``OperandIdentityHazard`` on a bad shared-operand dispatch) and the static
analyses (``repro.analysis.certify`` replays a trace and re-derives the
legality of every OoO decision). It lives in ``core`` — below both — so
neither layer imports the other.

A ``ScheduleTrace`` is the OoO JIT's audit log: program admissions,
per-superkernel group membership with per-op ``(stream, prog_uid, tag,
seq)`` identity, stagger/WAIT events, and the engine-level request
lifecycle (admit / retire / evict / unfinished). It is lightweight by
construction — tuples of ids, keys and floats, never arrays — so recording
it per tick costs O(group size) appends.

Hazard classes (the certifier's rejection taxonomy; see
``repro.analysis`` for the full discussion):

  * ``ProgramOrderHazard``   — per-stream program order broken: an op ran
    before its predecessor in the same program, or two ops of one stream
    were packed into a single (concurrent) superkernel group.
  * ``KVAliasHazard``        — two ops in one coalesced group belong to
    programs whose declared KV-cache write sets overlap (same cache
    owner + slot): concurrent writers to one KV row.
  * ``EnvAliasHazard``       — two ops in one group write the same key of
    the SAME program environment (programs are supposed to have private
    envs; a shared env dict aliases every key in it).
  * ``OperandIdentityHazard``— the shared-operand dispatch regime
    (``clustering.shared_weight_key``) packed ops whose weight closures
    resolve to DIFFERENT arrays: one weight load would silently serve the
    wrong tenant.
  * ``DeadlineHazard``       — EDF bookkeeping broke monotonicity: within
    one program, ``latest_start_t`` must be non-decreasing in program
    order (the remaining critical path only shrinks) and the program
    deadline must stay constant across its ops.
  * ``ConservationHazard``   — request accounting does not balance: an
    admitted request neither retired, was evicted, nor surfaced in
    ``ServeReport.unfinished``; or a request retired/was admitted more
    than once.
  * ``PlacementHazard``      — multi-device placement broke: a coalesced
    group mixed ops assigned to different devices, or an op was
    dispatched on a device other than its admission-time assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Set, Tuple


class HazardViolation(Exception):
    """Base class for every certified-schedule violation.

    ``detail`` carries the offending edge/record as data (machine
    readable); the message is the human rendering of the same facts.
    """

    kind = "hazard"

    def __init__(self, message: str, detail: Any = None):
        super().__init__(message)
        self.detail = detail


class ProgramOrderHazard(HazardViolation):
    kind = "program-order"


class KVAliasHazard(HazardViolation):
    kind = "kv-alias"


class EnvAliasHazard(HazardViolation):
    kind = "env-alias"


class OperandIdentityHazard(HazardViolation):
    kind = "operand-identity"


class DeadlineHazard(HazardViolation):
    kind = "deadline"


class ConservationHazard(HazardViolation):
    kind = "conservation"


class PlacementHazard(HazardViolation):
    kind = "placement"


@dataclasses.dataclass
class OpRecord:
    """One op's identity inside a dispatched superkernel group.

    ``env_id`` qualifies ``env_writes``: env keys are program-private, so
    a cross-program collision is only real when the env OBJECT is shared.
    ``weight_id`` is the identity (ids) of the array(s) the op's weight
    closure resolved to at dispatch time — what the operand-sharing check
    compares, since equal weight KEYS are supposed to imply identical
    arrays."""

    op_id: int
    stream: int
    prog_uid: int
    tag: str
    seq: int
    op_kind: str                          # "decode" | "prefill"
    deadline_t: float
    latest_start_t: float
    weight_key: Optional[Tuple]
    weight_id: Optional[Tuple]
    kv_writes: Tuple = ()                 # (("kv", owner, slot), ...)
    env_writes: Tuple = ()                # declared write keys, or ("*",)
    env_id: int = 0
    device: int = 0                       # admission-time device placement


@dataclasses.dataclass
class DispatchRecord:
    """One superkernel dispatch: the coalesced group at virtual time t.

    ``device`` is where the group actually launched — the certifier's
    placement check requires every member op's assigned device to equal
    it (a group can neither mix devices nor run somewhere else)."""

    t: float
    ops: Tuple[OpRecord, ...]
    shared_operand: bool = False
    device: int = 0


@dataclasses.dataclass
class ProgramAdmit:
    """One program joining the live pool (decode step or prefill pass)."""

    prog_uid: int
    stream: int
    kind: str
    req_ids: Tuple[int, ...] = ()
    kv_writes: Tuple = ()
    device: int = 0                       # admission-time device placement


@dataclasses.dataclass
class ScheduleTrace:
    """The audit log one ``JitSession`` (plus its serving engine) emits.

    The session records ``prog_admits`` / ``dispatches`` / ``waits``; the
    serving engine — which owns the request lifecycle — records
    ``req_admits`` / ``req_retires`` and fills ``evicted`` / ``unfinished``
    when the run ends. Raw ``VLIWJit`` sessions leave the request-level
    fields empty, which the conservation check treats as vacuously
    balanced."""

    prog_admits: List[ProgramAdmit] = dataclasses.field(default_factory=list)
    dispatches: List[DispatchRecord] = dataclasses.field(default_factory=list)
    waits: List[float] = dataclasses.field(default_factory=list)
    # engine-level request lifecycle
    req_admits: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)          # (req_id, t)
    req_retires: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)          # (req_id, t)
    evicted: Set[int] = dataclasses.field(default_factory=set)
    unfinished: Set[int] = dataclasses.field(default_factory=set)
    # multi-device request placement: which device each request was
    # admitted on / retired from. Kept as separate dicts (not widened
    # tuples in req_admits/req_retires) so single-device consumers of the
    # 2-tuple schema are untouched; the per-device conservation check
    # requires retire_devices[r] == req_devices[r] for every request.
    req_devices: dict = dataclasses.field(default_factory=dict)
    retire_devices: dict = dataclasses.field(default_factory=dict)
