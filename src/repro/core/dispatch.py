"""Jitted superkernel dispatch fast path — the steady-state execution layer.

The paper's thesis is that late-binding JIT dispatch recovers the spatial-
coalescing opportunity — but late binding only wins if the *dispatch* itself
stays off the critical path. The eager path (kernels/ops.py
``execute_superkernel``) pays an early-binding tax on every tick:

  * it re-pads and ``jnp.stack``s the **full weight matrices** of the group
    on every dispatch — O(model-weights) host traffic per tick, the
    dominant per-invocation overhead of fine-grained GPU multiplexing
    (D-STACK; the multi-tenant GPU inference surveys);
  * it runs pack → kernel → unpack as separate eager ops with exact
    max-(K, N) envelopes, so any group-shape churn retraces the
    ``coalesced_gemm`` ``pallas_call``.

``SuperkernelExecutor`` (owned by ``VLIWJit``, surviving sessions like the
plan caches) retires both:

  * **persistent packed-weight cache** — the padded/stacked weight operand
    of a group is cached in a ``PlanCache`` keyed by the group's ordered
    weight-key tuple + bucketed envelope, identity-guarded on the weight
    arrays themselves (the same discipline as the PR-2 program-template
    guard): a weight hot-swap produces new arrays, trips the guard, and is
    rebuilt — never served stale. Steady-state ticks re-send ZERO weight
    bytes (``DispatchStats.bytes_not_copied`` counts the traffic avoided).
  * **shape-bucketed superkernels** — every envelope extent is bucketed:
    per-problem rows to ``bm`` multiples with the total m-tile count a
    power of two, K and N to 128-floored powers of two
    (``kernels/ops.envelope_bucket``), and the problem/stacked-weight
    count G to an UNfloored power of two (``_pow2`` — flooring G at 128
    would stack 128 full weight copies per group). The jitted
    pack+kernel+unpack therefore hits JAX's compile cache instead of
    retracing per unique group shape.
  * **retrace-free steady state** — the whole dispatch (activation pack →
    ``coalesced_gemm``/``coalesced_gemv`` → per-problem unpack) is one
    ``jax.jit`` with a static group signature, including the
    ``shared_operand`` fast path and the ``coalesced_matvec`` regime. A
    module-level trace counter (incremented when a traced body actually
    runs) surfaces retraces in ``DispatchStats.retraces``; on a stable
    trace it stops moving after warmup (tests/test_dispatch.py).

Correctness contract: bucket padding is zeros, and adding ``+0.0`` terms to
an fp32 accumulator is exact — so whenever the bucketed K keeps the same
``bk`` contraction split as the eager exact envelope (all power-of-two
weight dims, e.g. every smoke config), the fast path is BIT-identical to
the eager reference (asserted in tests/test_dispatch.py, and end-to-end as
greedy-token identity in benchmarks/dispatch_bench.py). When bucketing
changes the contraction split (a non-power-of-two K like 300: eager
384/bk=384 vs bucketed 512/bk=512), fp32 reduction regrouping shifts the
last ulps — numerically equivalent (see the ragged-dims test's 1e-4
tolerance), but a greedy argmax at an exact logit tie could differ, so
token identity for such models is an empirical property, not a guarantee.

Memory note: cached packed weights are full padded copies — on a real
deployment this is the point (the packed operand lives in HBM across ticks
instead of being re-staged), but the footprint must be bounded in BYTES,
not entries (one entry can be hundreds of MB at real model sizes):
``VLIWJit(weight_budget_bytes=...)`` sets the LRU byte budget (default
1 GiB), ``weight_capacity`` the entry count, and ``capacity=0`` disables
the cache entirely (the repack-per-tick baseline, still jitted).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.clustering import matvec_weight_key
from repro.core.costmodel import BlockConfig
from repro.core.kernelspec import KernelOp
from repro.core.schedtrace import OperandIdentityHazard
from repro.core.plancache import PlanCache
from repro.kernels.coalesced_gemm import coalesced_gemm
from repro.kernels.coalesced_gemv import coalesced_gemv
from repro.kernels.ops import (_round_up, check_vmem, coalesced_matvec,
                               envelope_bucket, execute_superkernel,
                               interpret_default)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DispatchStats:
    """Counters for the jitted dispatch fast path. Supports ``+``/``-`` so
    per-session deltas fold through ``JitStats.merge`` like every other
    counter (the executor outlives sessions; each ``JitSession`` snapshots
    the executor's stats and reports only its own delta)."""

    dispatches: int = 0
    weight_hits: int = 0           # packed-weight operand served from cache
    weight_misses: int = 0         # packed/stacked + staged this dispatch
    weight_invalidations: int = 0  # identity-guard trips (weight hot-swap)
    retraces: int = 0              # jitted dispatch bodies actually traced
    bytes_not_copied: int = 0      # packed-weight bytes NOT re-staged (hits)

    @property
    def weight_hit_rate(self) -> float:
        n = self.weight_hits + self.weight_misses
        return self.weight_hits / n if n else 0.0

    def copy(self) -> "DispatchStats":
        return dataclasses.replace(self)

    def _combine(self, other: "DispatchStats", sign: int) -> "DispatchStats":
        return DispatchStats(
            *(getattr(self, f.name) + sign * getattr(other, f.name)
              for f in dataclasses.fields(self)))

    def __add__(self, other: "DispatchStats") -> "DispatchStats":
        return self._combine(other, +1)

    def __sub__(self, other: "DispatchStats") -> "DispatchStats":
        return self._combine(other, -1)


# ---------------------------------------------------------------------------
# the jitted dispatch bodies (module-level: one process-wide compile cache)
# ---------------------------------------------------------------------------

_TRACE_COUNT = 0


def trace_count() -> int:
    """Process-wide count of jitted-dispatch traces (compiles). The body of
    a ``jax.jit`` function runs exactly once per (shape, static-arg) cache
    entry, so the delta across a call window counts retraces."""
    return _TRACE_COUNT


def _mark_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


@functools.partial(jax.jit, static_argnames=("n_real", "m_tiles", "bm", "bn",
                                             "bk", "interpret"))
def _dispatch_grouped(activations, b_stacked, group_ids, *, n_real, m_tiles,
                      bm, bn, bk, interpret):
    """pack → grouped GEMM → unpack, one compiled executable.

    activations: tuple of [m_i, k_i] (k_i ≤ K); b_stacked: [G_pad, K, N];
    group_ids: [m_tiles] int32 (pad tiles point at group 0 — their zero
    activation rows produce zero output rows, sliced off below)."""
    _mark_trace()
    K = b_stacked.shape[1]
    parts = [jnp.pad(a, ((0, _round_up(a.shape[0], bm) - a.shape[0]),
                         (0, K - a.shape[1]))) for a in activations]
    a_packed = jnp.concatenate(parts, axis=0)
    a_packed = jnp.pad(a_packed,
                       ((0, m_tiles * bm - a_packed.shape[0]), (0, 0)))
    out = coalesced_gemm(a_packed, b_stacked, group_ids, bm=bm, bn=bn, bk=bk,
                         interpret=interpret)
    outs, s = [], 0
    for a, n in zip(activations, n_real):
        outs.append(out[s:s + a.shape[0], :n])
        s += _round_up(a.shape[0], bm)
    return tuple(outs)


@functools.partial(jax.jit, static_argnames=("n_real", "m_tiles", "bm", "bn",
                                             "bk", "interpret"))
def _dispatch_shared(activations, b_padded, *, n_real, m_tiles, bm, bn, bk,
                     interpret):
    """Shared-operand fast path: all problems use ONE weight matrix (the
    RNN/decode lockstep case) — activations concatenate into a single GEMM
    so the weight panel streams through VMEM once."""
    _mark_trace()
    K = b_padded.shape[0]
    x = jnp.concatenate(activations, axis=0)
    xp = jnp.pad(x, ((0, m_tiles * bm - x.shape[0]), (0, K - x.shape[1])))
    out = coalesced_gemm(xp, b_padded[None],
                         jnp.zeros((m_tiles,), jnp.int32),
                         bm=bm, bn=bn, bk=bk, interpret=interpret)
    outs, s = [], 0
    for a in activations:
        outs.append(out[s:s + a.shape[0], :n_real])
        s += a.shape[0]
    return tuple(outs)


@functools.partial(jax.jit, static_argnames=("n_real", "bn", "bk",
                                             "interpret"))
def _dispatch_matvec(xs, w_stacked, *, n_real, bn, bk, interpret):
    """Distinct-weights matvec regime: G_pad vectors against G_pad stacked
    weight panels via ``coalesced_gemv``. The CALLER owns G-bucket padding
    (``matvec`` extends ``xs``/``n_real`` with zero vectors to match
    ``w_stacked``'s leading dim) so exactly one layer decides the bucket."""
    _mark_trace()
    assert len(xs) == w_stacked.shape[0], (len(xs), w_stacked.shape)
    K = w_stacked.shape[1]
    xp = jnp.stack([jnp.pad(x, (0, K - x.shape[0])) for x in xs])
    out = coalesced_gemv(xp, w_stacked, bn=bn, bk=bk, interpret=interpret)
    return tuple(out[i, :n] for i, n in enumerate(n_real))


def _pow2(n: int) -> int:
    """Smallest power of two ≥ n (n ≥ 1)."""
    return 1 << max(n - 1, 0).bit_length()


def _tile_bucket(rows: Sequence[int], bm: int) -> int:
    """Power-of-two m-tile count covering per-problem rows padded to ``bm``
    multiples (``rows`` already concatenated tightly for the shared path is
    handled by passing the single total)."""
    return _pow2(sum(_round_up(m, bm) // bm for m in rows))


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

class SuperkernelExecutor:
    """Zero-copy, zero-retrace steady-state superkernel execution.

    Owned by ``VLIWJit`` (persistent across sessions, like the plan
    caches); ``JitSession.tick`` hands it the planned op group and gets the
    per-problem outputs back. ``enabled=False`` falls back to the eager
    reference path (``execute_superkernel``) — the ablation baseline the
    dispatch benchmark and the bit-identity tests measure against.
    """

    def __init__(self, weight_cache: Optional[PlanCache] = None, *,
                 bm: int = 8, bn: int = 128, bk: int = 512,
                 enabled: bool = True, interpret: Optional[bool] = None):
        assert bm & (bm - 1) == 0, f"bm must be a power of two, got {bm}"
        # the fallback cache is byte-budgeted too — packed-weight entries
        # are full padded copies, so an entry-count bound alone does not
        # bound memory (see the module docstring's memory note)
        self.weight_cache = weight_cache if weight_cache is not None \
            else PlanCache(256, byte_capacity=1 << 30)
        self.bm, self.bn, self.bk = bm, bn, bk
        self.enabled = enabled
        # resolved at construction from the CURRENT process default (not
        # the import-time value): a bench that probes the compiled lane
        # and falls back via ops.set_interpret gets interpret executors
        self.interpret = interpret_default() if interpret is None \
            else interpret
        self.stats = DispatchStats()

    # ------------------------------------------------------------------
    def _packed_weights(self, weights: Sequence[jax.Array],
                        wkeys: Sequence[Tuple], K: int, N: int, G_pad: int,
                        *, shared: bool, group=None,
                        device: int = 0) -> jax.Array:
        """The group's padded weight operand — [K, N] (shared) or
        [G_pad, K, N] (stacked) — from the persistent cache.

        Keyed by the ordered weight-key tuple + bucketed envelope and
        identity-guarded on the weight arrays themselves: a hot-swap that
        lands on the SAME key (same params object mutated in place) trips
        the guard and rebuilds, so the cache can never serve stale
        weights. A hot-swap that CHANGES the key (the serving path:
        replacing the params tree embeds a new ``id(params)`` in every
        weight key) is caught by ``group`` — a params-free identity of the
        logical dispatch slot (the ops' (stream, tag, seq) tuple) whose
        key change eagerly drops the superseded entry, instead of letting
        generations of full packed-weight copies (each pinning its old
        arrays via the guard) linger until LRU pressure. Both paths count
        in ``weight_invalidations``. On a hit, the bytes of the packed
        operand are counted as traffic NOT re-staged this tick.

        ``device`` is part of the key: per-device op pools share one
        executor (one VLIWJit-owned weight cache), and a packed operand
        modeled as resident on device 0's HBM must not satisfy a device-1
        dispatch — each device stages (and then retains) its own copy."""
        key = ("wpack", device, "shared" if shared else "stacked",
               tuple(wkeys), K, N, G_pad, str(weights[0].dtype))

        def build() -> jax.Array:
            parts = [jnp.pad(w, ((0, K - w.shape[0]), (0, N - w.shape[1])))
                     for w in weights]
            if shared:
                return parts[0]
            if G_pad > len(parts):
                pad = jnp.zeros((K, N), parts[0].dtype)
                parts.extend([pad] * (G_pad - len(parts)))
            return jnp.stack(parts, axis=0)

        inval0 = self.weight_cache.stats.invalidations
        value, hit = self.weight_cache.get_or_build_flagged(
            key, build, guard=tuple(weights), group=group)
        # accrued outside the hit/miss branch: a group-key change can drop
        # a superseded entry even on a call that then HITS (another slot
        # already rebuilt the new key), and that drop must still be counted
        self.stats.weight_invalidations += \
            self.weight_cache.stats.invalidations - inval0
        if hit:
            self.stats.weight_hits += 1
            self.stats.bytes_not_copied += int(value.nbytes)
        else:
            self.stats.weight_misses += 1
        return value

    # ------------------------------------------------------------------
    def stacked_operand(self, wkey: Tuple, k: int, n: int, layers: int,
                        weight_fn, guard: Sequence[jax.Array], *,
                        group=None, device: int = 0) -> jax.Array:
        """One LAYER-STACKED weight operand — [L, ..., K, N] padded to the
        bucketed (K, N) envelope — from the persistent cache.

        This is the stacked-template analogue of ``_packed_weights``: one
        cache entry per stacked operand per params generation (entry count
        per tenant O(#operands), not O(#operands × layers)), m-free so the
        same entry serves decode, prefill and every batch size.

        ``weight_fn`` builds the raw stacked array lazily (typically a
        [lo:hi) slice of the params tree's stacked blocks) — it only runs
        on a miss. ``guard`` must be the ORIGINAL stacked params arrays
        (stable across ticks), never per-build slices: a fresh slice every
        tick would read as a phantom hot-swap and repack the whole stack.
        A real hot-swap replaces the params tree → new ``id(params)`` in
        ``wkey`` → new cache key; ``group`` (params-free slot identity)
        eagerly drops the superseded entry, exactly like
        ``_packed_weights``."""
        K = envelope_bucket(int(k))
        N = envelope_bucket(int(n))
        # device id keyed for the same reason as _packed_weights: the
        # shared cache holds one resident stack PER DEVICE
        key = ("wstack", device, wkey, int(layers), K, N,
               str(guard[0].dtype) if guard else "")

        def build() -> jax.Array:
            w = weight_fn()
            pad = [(0, 0)] * (w.ndim - 2) + [(0, K - int(w.shape[-2])),
                                             (0, N - int(w.shape[-1]))]
            return jnp.pad(w, pad)

        inval0 = self.weight_cache.stats.invalidations
        value, hit = self.weight_cache.get_or_build_flagged(
            key, build, guard=tuple(guard), group=group)
        self.stats.weight_invalidations += \
            self.weight_cache.stats.invalidations - inval0
        if hit:
            self.stats.weight_hits += 1
            self.stats.bytes_not_copied += int(value.nbytes)
        else:
            self.stats.weight_misses += 1
        return value

    # ------------------------------------------------------------------
    def execute(self, ops: Sequence[KernelOp], *,
                shared_operand: bool = False,
                interpret: Optional[bool] = None,
                device: int = 0,
                block: Optional[BlockConfig] = None) -> List[jax.Array]:
        """Execute a planned group; returns per-problem outputs in op order.

        Each op carries its operand binding (``op.payload`` =
        (activation, weight, weight_key), attached by
        ``JitSession._push_op``). ``block`` overrides the executor's
        default (bm, bn, bk) for THIS dispatch — the live-tuned config of
        the planned group (``SuperkernelPlan.block`` when
        ``VLIWJit(live_tune=True)``). The override enters the jitted
        bodies as static args, so each DISTINCT tuned config compiles
        once (a warmup trace, like any first-seen envelope bucket) and a
        group whose signature — and therefore tuned config — is stable
        never retraces; config churn that lands back on an already-seen
        config is a pure compile-cache hit, never a spurious retrace."""
        # pack in CANONICAL op order: the scheduler sorts a group by
        # urgency, so the same set of ops can arrive in different orders
        # tick to tick — an order-sensitive key would fork duplicate
        # packed-weight entries (and orphan some from the group tag's
        # eager hot-swap drop). Outputs are restored to call order below.
        order = sorted(range(len(ops)),
                       key=lambda i: (ops[i].stream_id, ops[i].tag,
                                      ops[i].seq_index))
        problems = [ops[i].payload[:2] for i in order]
        wkeys = [ops[i].payload[2] for i in order]
        if shared_operand:
            # the shared regime loads ops[0]'s weight ONCE for the whole
            # group, so equal weight keys must mean the identical array —
            # a key aliasing two distinct arrays (e.g. a weight_fn that
            # rebuilds a transpose per template) would silently serve one
            # tenant another's weights. Fail loudly instead; the schedule
            # certifier (repro.analysis.certify) makes the same check on
            # the recorded trace.
            w0 = problems[0][1]
            bad = next((i for i, (_, w) in enumerate(problems)
                        if w is not w0), None)
            if bad is not None:
                raise OperandIdentityHazard(
                    "shared-operand dispatch over non-identical weight "
                    f"arrays: key {wkeys[0]} vs {wkeys[bad]}",
                    detail={"keys": (wkeys[0], wkeys[bad])})
        # params-free identity of this dispatch slot, so a hot-swap that
        # renames every weight key (new id(params)) still eagerly drops
        # the superseded packed-weight entry (see _packed_weights)
        group = (tuple((ops[i].stream_id, ops[i].tag, ops[i].seq_index)
                       for i in order), shared_operand, device)
        canon = self.execute_problems(problems, wkeys,
                                      shared_operand=shared_operand,
                                      interpret=interpret, group=group,
                                      device=device, block=block)
        outs: List[Optional[jax.Array]] = [None] * len(ops)
        for pos, i in enumerate(order):
            outs[i] = canon[pos]
        return outs

    def execute_problems(self, problems, wkeys, *,
                         shared_operand: bool = False,
                         interpret: Optional[bool] = None,
                         group=None, device: int = 0,
                         block: Optional[BlockConfig] = None
                         ) -> List[jax.Array]:
        interpret = self.interpret if interpret is None else interpret
        # per-dispatch tile override (live tuning); tuner candidates are
        # power-of-two, which the m-tile bucketing below relies on
        bm, bn, bk = (self.bm, self.bn, self.bk) if block is None else \
            (block.bm, block.bn, block.bk)
        assert bm & (bm - 1) == 0, f"bm must be a power of two, got {bm}"
        if not self.enabled:
            return execute_superkernel(problems, bm=bm, bn=bn, bk=bk,
                                       shared_operand=shared_operand,
                                       interpret=interpret)
        acts = tuple(a for a, _ in problems)
        ws = [w for _, w in problems]
        G = len(acts)
        self.stats.dispatches += 1
        trace0 = trace_count()
        # bucket the problem COUNT too: the activation tuple's arity is
        # part of the jit trace key, so a group shrinking from 8 to 7
        # same-shape problems would otherwise retrace. Pad entries are
        # zero activations (cheapest member's shape) whose outputs are
        # dropped — for homogeneous groups, any G in one bucket shares
        # one traced signature.
        G_pad = _pow2(G)
        if G_pad > G:
            pad = jnp.zeros_like(min(acts, key=lambda a: int(a.shape[0])))
            acts = acts + (pad,) * (G_pad - G)
        if shared_operand:
            w = ws[0]
            K = envelope_bucket(int(w.shape[0]))
            N = envelope_bucket(int(w.shape[1]))
            m_tiles = _tile_bucket([sum(int(a.shape[0]) for a in acts)],
                                   bm)
            b = self._packed_weights([w], [wkeys[0]], K, N, 1, shared=True,
                                     group=group, device=device)
            check_vmem(bm, min(bn, N), min(bk, K),
                       dtype_bytes=b.dtype.itemsize, interpret=interpret)
            outs = _dispatch_shared(
                acts, b, n_real=int(w.shape[1]), m_tiles=m_tiles,
                bm=bm, bn=min(bn, N), bk=min(bk, K),
                interpret=interpret)
        else:
            K = envelope_bucket(max(int(w.shape[0]) for w in ws))
            N = envelope_bucket(max(int(w.shape[1]) for w in ws))
            b = self._packed_weights(ws, wkeys, K, N, G_pad, shared=False,
                                     group=group, device=device)
            n_real = [int(w.shape[1]) for w in ws]
            n_real += [n_real[0]] * (G_pad - G)
            m_tiles = _tile_bucket([int(a.shape[0]) for a in acts], bm)
            gids = []
            for g, a in enumerate(acts):
                # pad problems read group 0's weights: their activations
                # are zero, so the product is zero and never read back
                gids.extend([g if g < G else 0]
                            * (_round_up(int(a.shape[0]), bm)
                               // bm))
            gids.extend([0] * (m_tiles - len(gids)))  # pad tiles: group 0
            check_vmem(bm, min(bn, N), min(bk, K),
                       dtype_bytes=b.dtype.itemsize, interpret=interpret)
            outs = _dispatch_grouped(
                acts, b, jnp.asarray(gids, jnp.int32),
                n_real=tuple(n_real),
                m_tiles=m_tiles, bm=bm, bn=min(bn, N),
                bk=min(bk, K), interpret=interpret)
        self.stats.retraces += trace_count() - trace0
        return list(outs[:G])

    # ------------------------------------------------------------------
    def matvec(self, xs: Sequence[jax.Array], ws: Sequence[jax.Array], *,
               interpret: Optional[bool] = None,
               group=None) -> List[jax.Array]:
        """Jitted ``coalesced_matvec``: G matvecs (x [k], w [k, n]) with the
        stacked weight operand cached persistently (keyed on the weight
        arrays' identity). Dispatches the shared-weight GEMM regime when
        every problem uses the same weight array, exactly like the eager
        ``kernels.ops.coalesced_matvec``.

        A caller that hot-swaps its weights should pass a stable ``group``
        (any hashable identity of ITS dispatch slot): the ``id(w)``-based
        keys change with every swap, and without a group tag the
        superseded packed stacks — each pinning its dead weight arrays via
        the guard — are only reclaimed by the cache's LRU/byte bounds."""
        interpret = self.interpret if interpret is None else interpret
        if not self.enabled:
            return coalesced_matvec(xs, ws, interpret=interpret)
        if all(w is ws[0] for w in ws):
            outs = self.execute_problems(
                [(x[None, :], ws[0]) for x in xs],
                [matvec_weight_key(ws[0], shared=True)] * len(xs),
                shared_operand=True, interpret=interpret, group=group)
            return [o[0] for o in outs]
        self.stats.dispatches += 1
        trace0 = trace_count()
        G = len(xs)
        G_pad = _pow2(G)
        K = envelope_bucket(max(int(w.shape[0]) for w in ws))
        N = envelope_bucket(max(int(w.shape[1]) for w in ws))
        wkeys = [matvec_weight_key(w) for w in ws]
        w_stacked = self._packed_weights(ws, wkeys, K, N, G_pad,
                                         shared=False, group=group)
        xs = tuple(xs)
        n_real = [int(w.shape[1]) for w in ws]
        if G_pad > G:
            xs = xs + (jnp.zeros_like(xs[0]),) * (G_pad - G)
            n_real += [n_real[0]] * (G_pad - G)
        outs = _dispatch_matvec(
            xs, w_stacked, n_real=tuple(n_real),
            bn=min(self.bn, N), bk=min(self.bk, K), interpret=interpret)
        self.stats.retraces += trace_count() - trace0
        return list(outs[:G])
