"""The OoO VLIW JIT runtime — real, event-driven execution path.

This is the paper's Figure 1 made concrete: multiple tenant streams, each an
*instruction stream* of declared kernel ops, multiplexed onto one device by
(a) clustering + coalescing compatible GEMMs into Pallas superkernels and
(b) OoO, SLO-aware interleaving of the streams.

Execution model (TPU adaptation, DESIGN.md §2): a tenant's decode step is
compiled into a ``KernelProgram`` — an alternating sequence of GEMM stages
(declared to the JIT, coalescible across tenants) and glue stages (norms,
rope, cache updates, softmax — executed eagerly per tenant). Prompt
prefills compile the same way (``build_dense_prefill_template``): the
prompt length is the GEMM m dimension, padded to a power-of-two bucket
(``prefill_bucket``), and the program epilogue writes the request's KV rows
into the tenant's slotted cache — so long prompts enter the live op pool
and coalesce with decode (and other tenants' prefill) traffic instead of
serializing the device (``JitStats.prefill_coalesced``).

Non-dense tenants are first-class streams too: MoE decode steps compile
with the router/dispatch as glue and 3·E per-expert FFN ``GemmStage``s
(``build_moe_decode_template`` — same expert GEMMs coalesce across tenants,
``JitStats.expert_coalesced``), and SSM (Mamba-2/SSD) decode steps compile
with the in/out projections declared and the selective-scan recurrence as
glue (``build_ssm_decode_template``) — the paper's heterogeneous-tenant
multiplexing scenario, not just same-family dense fleets.

The runtime is a **virtual-time event loop**, not a round barrier. A
``JitSession`` keeps the scheduler, the live op pool and the stats open
across calls so that:

  * programs are admitted **mid-flight** — a new tenant's ``KernelProgram``
    joins the live pool *between superkernel dispatches*, not at a round
    boundary (``JitStats.mid_flight_admissions`` counts these);
  * the caller feeds the next known future admission into
    ``OoOScheduler.next_arrival_t``, so the scheduler's stagger/WAIT branch
    (paper §5.2: "purposefully delays ill-fitting kernels for better
    coalescing at a slightly later time") executes on the real path
    (``JitStats.waits``);
  * per-request SLOs flow into per-op ``latest_start_t`` via the program's
    remaining-GEMM critical path, driving EDF anchoring and the eviction of
    already-missed stragglers (``JitStats.evictions``).

``VLIWJit.run`` is the closed-world convenience wrapper: it opens a session,
admits the given programs (plus an optional timed ``arrivals`` schedule) and
ticks the loop to completion.

Scheduler overhead stays off the critical path via the persistent plan
caches (core/plancache.py) owned by the ``VLIWJit`` and surviving sessions:
``plan_cache`` holds compiled ``ProgramTemplate``s — the serving engine
rebinds only per-step state (tokens, KV cache refs, deadlines) on
steady-state ticks — and ``block_plans`` memoizes the coalescer's
superkernel block choice per group signature. Per-session cache deltas are
reported in ``JitStats.plan_cache`` / ``JitStats.block_plans``.

Execution overhead stays off the critical path via the ``VLIWJit``-owned
``SuperkernelExecutor`` (core/dispatch.py): packed weight operands are
cached persistently (never re-staged in steady state), envelopes are
bucketed to powers of two, and the whole pack→kernel→unpack dispatch is
one jitted executable — so a stable trace runs zero-copy and zero-retrace
after warmup (``JitStats.dispatch``).

**Layer-stacked templates (scan-over-layers).** By default
(``stacked_layers=True`` throughout) the builders emit ONE scanned layer
body per homogeneous sub-stack of layers instead of ~6 stages per layer:
the params tree already stores weights stacked along a leading layer axis,
so a ``StackedGemmStage`` declares the whole sub-stack as one schedulable
op whose operands are the stacked ``blocks`` arrays ([L, k, n] per
projection, [L, E, k, n] for MoE expert packs) and whose execution is a
jitted ``jax.lax.scan`` over the layer axis — template build, trace size
and plan/weight-cache entries become O(1) in depth. Design points:

  * weight-key schema (``clustering.weight_key`` is the single
    constructor): stacked operands drop the layer index —
    ``(model, pid, "stack", lo, hi, name[, expert])`` names ONE stacked
    operand covering layers [lo, hi), so the dispatch executor caches
    O(#operands) packed entries per tenant instead of O(#operands · L);
  * sub-stack partitioning (``partition_layers``): non-homogeneous stacks
    — gemma-style local/global attention alternation — split into maximal
    homogeneous runs, each scanned separately (``is_global`` must be
    static inside one scan body);
  * scan carry layout: the residual stream ``x [B, d]`` is the carry;
    per-layer xs are the norm scales, the layer's KV (or conv/h) cache
    slices and the padded stacked weights; ys stack the per-layer cache
    updates, which the epilogue concatenates back into the tenant's cache
    — the same [L, ...] layout the per-layer path's ``jnp.stack`` built;
  * the scan body's GEMMs (``_scan_gemm``) replicate the dispatch
    executor's solo-dispatch bucketing EXACTLY (same m-tile bucket, same
    power-of-two envelopes, same block sizes), which is what makes the
    stacked path bit-identical to per-layer emission
    (tests/test_stacked_templates.py asserts logits AND cache identity
    for dense decode/prefill, MoE and SSM);
  * cost/coalescing granularity: a stacked op is charged as L sequential
    tile-waves (``GemmShape.layers``), clusters on its full stack
    signature (``clustering.coalesce_key``) so only same-depth-and-dims
    tenants coalesce entire stacks, and carries the dominant operand's
    shape for EDF/aspect bookkeeping.

``stacked_layers=False`` (builders + ServingEngine) keeps the per-layer
emission path alive as the bit-identity oracle.

Correctness: running a program must produce bit-comparable results to the
monolithic ``Model.decode_step`` (tests/test_jit_engine.py), regardless of
admission timing (tests/test_event_loop.py).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.autotuner import LiveTuner
from repro.core.clustering import (is_expert_op, op_weight_identity,
                                   op_weight_key, shared_weight_key,
                                   weight_key)
from repro.core.coalescer import Coalescer
from repro.core.costmodel import BlockConfig, CostModel, GemmShape, TPUV5E
from repro.core.dispatch import (DispatchStats, SuperkernelExecutor,
                                 _tile_bucket, envelope_bucket)
from repro.core.kernelspec import make_op, op_aspect
from repro.core.plancache import PlanCache, PlanCacheStats
from repro.core.scheduler import OoOScheduler, SchedulerConfig
from repro.core.schedtrace import (DispatchRecord, OpRecord, ProgramAdmit,
                                   ScheduleTrace)
from repro.kernels.coalesced_gemm import coalesced_gemm
from repro.models.layers import rmsnorm, apply_rope


# ---------------------------------------------------------------------------
# kernel programs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GemmStage:
    tag: str                       # cluster tag, e.g. "L3.ffn_gate"
    weight_key: Tuple              # identity key for operand sharing
    weight_fn: Callable[[], jax.Array]
    # consumes env, returns the activation matrix [m, k]
    input_fn: Callable[[Dict[str, Any]], jax.Array]
    # receives (env, gemm_output)
    output_fn: Callable[[Dict[str, Any], jax.Array], None]
    # statically-known problem shape; lets deadline annotation cost the
    # stage without materializing its weight (weight_fn may be non-trivial,
    # e.g. a tied-embedding transpose)
    shape: Optional[GemmShape] = None
    # declared access sets for static dependence analysis
    # (repro.analysis.depgraph): the env keys input_fn/output_fn touch —
    # plus the reserved "cache" / "new_layers" resources for stages that
    # read or update KV state. None (undeclared) means the analysis must
    # conservatively assume the stage aliases EVERYTHING; the builders in
    # this module declare every stage they emit.
    reads: Optional[Tuple] = None
    writes: Optional[Tuple] = None


@dataclasses.dataclass
class GlueStage:
    fn: Callable[[Dict[str, Any]], None]
    # declared access sets (see GemmStage.reads/writes): what the eager
    # glue closure reads and writes in the program env. Undeclared glue
    # aliases everything, which serializes it against every neighbor in
    # the dependence graph.
    reads: Optional[Tuple] = None
    writes: Optional[Tuple] = None


def partition_layers(flags: Sequence[bool]) -> List[Tuple[int, int]]:
    """Partition a layer-flag sequence into maximal homogeneous runs.

    Returns half-open ``(lo, hi)`` spans covering ``range(len(flags))``
    exactly once, in order, with the flag constant inside each span — the
    sub-stacks a non-homogeneous model (``layer_is_global`` alternation)
    scans separately, because the flag must be static inside one scan
    body. A homogeneous depth-L model yields the single span ``(0, L)``.
    """
    runs: List[Tuple[int, int]] = []
    lo = 0
    for i in range(1, len(flags)):
        if flags[i] != flags[lo]:
            runs.append((lo, i))
            lo = i
    if len(flags):
        runs.append((lo, len(flags)))
    return runs


@dataclasses.dataclass
class StackedOperand:
    """One stacked weight operand of a scanned layer body: a
    ``[Lsub, ..., k, n]`` array covering a homogeneous sub-stack of layers
    (MoE expert packs carry an extra expert axis). ``shape.layers`` counts
    the operand's sequential tile-waves — Lsub for dense operands,
    Lsub·E for expert packs (each scan step runs E expert GEMMs)."""

    tag: str                       # per-layer stage tag, e.g. "ffn_gate"
    weight_key: Tuple              # clustering.weight_key(..., stack=...)
    shape: GemmShape               # per-wave (m, n, k) with layers = waves
    # lazy builder of the raw stacked array (a [lo:hi) view of the params
    # tree's stacked blocks) — only runs on an operand-cache miss
    weight_fn: Callable[[], jax.Array]
    # identity guard: the ORIGINAL stacked params arrays (stable across
    # ticks) — never per-build slices, which would read as phantom
    # hot-swaps and repack the whole stack every tick
    guard: Tuple = ()


@dataclasses.dataclass
class StackedGemmStage:
    """One scanned layer body: a whole homogeneous sub-stack of layers as
    a single schedulable op (the stacked-template analogue of ~6·Lsub
    ``GemmStage``s). The session fetches each operand's padded stack from
    the executor's persistent cache (``SuperkernelExecutor.
    stacked_operand``) and calls ``run`` — a jitted ``jax.lax.scan`` whose
    body replays the per-layer math with ``_scan_gemm`` standing in for
    the executor's solo dispatch, bit-identically."""

    tag: str                       # body identity, e.g. "body_0_12"
    weight_key: Tuple              # clustering.weight_key("body", stack=...)
    operands: List[StackedOperand]
    layers: int                    # hi - lo
    # run(env, {operand tag -> padded stacked array}, executor): executes
    # the scan and writes results (residual stream, cache updates) to env
    run: Callable[[Dict[str, Any], Dict[str, jax.Array],
                   SuperkernelExecutor], None]
    # declared access sets (see GemmStage.reads/writes): a scanned body
    # reads the residual stream + cache slices and writes the residual
    # stream + its cache-update chunk
    reads: Optional[Tuple] = None
    writes: Optional[Tuple] = None


Stage = Any  # GemmStage | GlueStage | StackedGemmStage

# monotonically-increasing KernelProgram instance ids (trace identity)
_PROG_UIDS = itertools.count(1)


def _scan_gemm(a: jax.Array, w_pad: jax.Array, n_real: int, *, bm: int,
               bn: int, bk: int, interpret: bool) -> jax.Array:
    """One GEMM inside a scanned layer body, replicating the dispatch
    executor's solo dispatch EXACTLY — same m-tile bucket, same padded
    (K, N) envelope (``w_pad`` is one xs slice of a cached
    ``stacked_operand``), same block clamping — so a stacked body is
    bit-identical to the per-layer path dispatching each stage."""
    m = int(a.shape[0])
    K, N = int(w_pad.shape[-2]), int(w_pad.shape[-1])
    m_tiles = _tile_bucket([m], bm)
    ap = jnp.pad(a, ((0, m_tiles * bm - m), (0, K - int(a.shape[1]))))
    out = coalesced_gemm(ap, w_pad[None], jnp.zeros((m_tiles,), jnp.int32),
                         bm=bm, bn=min(bn, N), bk=min(bk, K),
                         interpret=interpret)
    return out[:m, :n_real]


def _stack_slice(arr: jax.Array, lo: int, hi: int) -> jax.Array:
    return arr if lo == 0 and hi == int(arr.shape[0]) else arr[lo:hi]


@dataclasses.dataclass
class KernelProgram:
    """One tenant step: stages + a private environment."""
    stream_id: int
    stages: List[Stage]
    env: Dict[str, Any]
    pc: int = 0
    slo_s: float = float("inf")
    arrival_t: float = 0.0
    # absolute request deadline; when left inf it falls back to
    # arrival_t + slo_s. Carrying it explicitly keeps the deadline exact
    # across successive step programs of one tenant (no float roundtrip
    # through slo_s = deadline - now), which the scheduler's per-
    # (stream, deadline) eviction dedup relies on.
    deadline_t: float = float("inf")
    batch: int = 1                 # activation rows (m) of every GEMM stage
    # serving phase this program implements: "decode" (one step of a slotted
    # batch) or "prefill" (a whole prompt pass whose epilogue writes the
    # request's KV rows into the tenant's cache). Plumbed onto every op the
    # program emits (KernelOp.op_kind) for the scheduler's coalescing stats.
    kind: str = "decode"
    # (req_id, final deadline) per request batched into this step. Plumbed
    # onto every KernelOp the program emits so the scheduler can account
    # SLO demotions per *request* — a straggler next to healthy batchmates
    # counts exactly once across steps, not zero times (hidden behind the
    # batch's healthy anchor deadline) or once per step.
    req_deadlines: Tuple = ()
    # KV-cache rows this program writes, as ("kv", owner, slot) resources —
    # the serving engine binds the tenant's cache identity + slot indices
    # (all batch rows for a decode step, the reserved slot for a prefill).
    # Ops inherit the set on their trace records; the schedule certifier
    # rejects any coalesced group whose members' sets overlap (two
    # concurrent writers to one KV row). Empty for raw programs — no
    # declared rows, no possible overlap.
    kv_writes: Tuple = ()
    # mesh placement: the device this program's ops execute on. Stamped by
    # JitSession.admit from the session's device id — one session drives
    # exactly one device's timeline, so a program never spans devices.
    device: int = 0
    # instance identity for trace records / program-order certification
    # (seq_index resets across a stream's successive step programs, so
    # (stream, seq) alone cannot express cross-program ordering)
    uid: int = dataclasses.field(
        default_factory=lambda: next(_PROG_UIDS), compare=False)
    _gemm_suffix: Optional[List[float]] = dataclasses.field(
        default=None, repr=False, compare=False)
    # set by ProgramTemplate.bind: programs bound from one template share
    # the template's memoized suffix instead of re-deriving it per step
    _suffix_fn: Optional[Callable[[CostModel], List[float]]] = \
        dataclasses.field(default=None, repr=False, compare=False)

    def done(self) -> bool:
        return self.pc >= len(self.stages)

    def advance_glue(self) -> Optional[Stage]:
        """Run glue stages until the next GEMM / stacked body stage (or
        completion)."""
        while self.pc < len(self.stages):
            st = self.stages[self.pc]
            if isinstance(st, (GemmStage, StackedGemmStage)):
                return st
            st.fn(self.env)
            self.pc += 1
        return None

    @property
    def effective_deadline(self) -> float:
        return self.deadline_t if math.isfinite(self.deadline_t) \
            else self.arrival_t + self.slo_s

    def remaining_gemm_time(self, cost: CostModel, pc: int) -> float:
        """Modeled critical-path seconds of the GEMM stages in
        ``stages[pc:]`` — the suffix the scheduler subtracts from the
        request deadline to get the current op's ``latest_start_t``."""
        if self._gemm_suffix is None:
            if self._suffix_fn is not None:
                self._gemm_suffix = self._suffix_fn(cost)
            else:
                self._gemm_suffix = _gemm_suffix_table(self.stages,
                                                       self.batch, cost)
        return self._gemm_suffix[pc]


def _gemm_suffix_table(stages: List[Stage], batch: int,
                       cost: CostModel) -> List[float]:
    """suffix[i] = modeled seconds of the GEMM stages in ``stages[i:]``."""
    suf = [0.0] * (len(stages) + 1)
    for i in range(len(stages) - 1, -1, -1):
        st = stages[i]
        dt = 0.0
        if isinstance(st, GemmStage):
            shape = st.shape
            if shape is None:
                w = st.weight_fn()
                shape = GemmShape(m=batch, n=int(w.shape[1]),
                                  k=int(w.shape[0]))
            dt = cost.gemm_time(shape)
        elif isinstance(st, StackedGemmStage):
            # every operand's GemmShape carries its wave count in .layers,
            # so the body's critical path is the plain sum of gemm_time
            dt = sum(cost.gemm_time(od.shape) for od in st.operands)
        suf[i] = suf[i + 1] + dt
    return suf


# ---------------------------------------------------------------------------
# program templates — the unit the plan cache stores
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ProgramTemplate:
    """A compiled-once tenant step: the stage list, glue closures and weight
    keys, with NO per-step state. ``bind()`` rebinds only the per-step
    environment (tokens, KV cache refs, deadlines) into a fresh lightweight
    ``KernelProgram`` — the steady-state hot path does this instead of
    re-deriving the whole stage list every tick.

    Validity contract (what the cache key must capture): the stages close
    over the model config, the params tree and the batch size m. Everything
    that varies per step is read out of the program env. Templates are
    therefore keyed by (model identity, batch m, dtype, cache geometry) and
    identity-guarded on the params object (core/plancache.py).
    """

    stages: List[Stage]
    batch: int
    model_name: str = ""
    # "decode": batch = the slotted batch m, tokens bound as [m, 1];
    # "prefill": batch = the padded prompt length (prefill bucket), tokens
    # bound as [1, batch] — the prompt IS the GEMM m dimension.
    kind: str = "decode"
    _suffix: Optional[List[float]] = dataclasses.field(
        default=None, repr=False, compare=False)
    _suffix_cost_id: Optional[int] = dataclasses.field(
        default=None, repr=False, compare=False)

    def gemm_suffix(self, cost: CostModel) -> List[float]:
        """Memoized per cost model — bound programs share one table."""
        if self._suffix is None or self._suffix_cost_id != id(cost):
            self._suffix = _gemm_suffix_table(self.stages, self.batch, cost)
            self._suffix_cost_id = id(cost)
        return self._suffix

    def bind(self, *, stream_id: int, tokens: jax.Array, cache,
             slo_s: float = float("inf"), arrival_t: float = 0.0,
             deadline_t: float = float("inf"),
             req_deadlines: Tuple = (),
             kv_writes: Tuple = (),
             env_extra: Optional[Dict[str, Any]] = None) -> KernelProgram:
        """Instantiate one step: fresh env + deadlines, shared stages.

        ``env_extra`` merges additional per-step entries into the program
        env (the prefill path binds ``real_len`` / ``slot`` / ``req``);
        ``kv_writes`` declares the ("kv", owner, slot) cache rows this
        step writes (see KernelProgram.kv_writes)."""
        if self.kind == "prefill":
            assert int(tokens.shape[1]) == self.batch, \
                (tokens.shape, self.batch)
        else:
            assert int(tokens.shape[0]) == self.batch, \
                (tokens.shape, self.batch)
        env: Dict[str, Any] = {"tokens": tokens, "cache": cache,
                               "new_layers": {"k": [], "v": []}}
        if env_extra:
            env.update(env_extra)
        return KernelProgram(stream_id=stream_id, stages=self.stages,
                             env=env, slo_s=slo_s, arrival_t=arrival_t,
                             deadline_t=deadline_t, batch=self.batch,
                             kind=self.kind,
                             req_deadlines=tuple(req_deadlines),
                             kv_writes=tuple(kv_writes),
                             _suffix_fn=self.gemm_suffix)


def dense_program_cache_key(model, params, batch: int, cache, *,
                            stacked: bool = True) -> Tuple:
    """Plan-cache key for a dense decode template: (model identity, active
    batch m, dtype, cache geometry). Params identity is deliberately NOT in
    the key — a weight hot-swap lands on the same slot and is caught by the
    cache's identity guard (``guard=(model, params)`` at the lookup site),
    which invalidates (and counts) instead of silently serving stale
    closures. The guard also pins both objects, so ``id(model)`` here can
    never be a recycled address aliasing a dead model.

    The emission regime and depth are part of the key: a stacked and a
    per-layer template of the same model must never alias, and stacked
    geometry (sub-stack spans) is a function of num_layers."""
    kc = cache["layers"]["k"]
    return ("dense-decode", model.cfg.name, id(model), batch,
            str(params["embed"].dtype), str(kc.dtype), tuple(kc.shape),
            ("stacked", bool(stacked), model.cfg.num_layers))


# ---------------------------------------------------------------------------
# program builders for dense GQA (the real-execution demo family)
# ---------------------------------------------------------------------------

def _emit_dense_body(cfg: ModelConfig, params, stages: List[Stage], *,
                     m_rows: int, attend_for, ffn_for=None,
                     attend_reads: Tuple = ("wq", "wk", "wv", "cache")
                     ) -> None:
    """Emit the per-layer stage scaffolding shared by the dense DECODE and
    PREFILL builders: pre-norm, the wq/wk/wv projections, the phase-specific
    attention glue (``attend_for(l, lp, is_global)``), wo, post-norm and the
    gated FFN. There is deliberately exactly ONE copy of this: cross-phase
    operand sharing (a prefill op loading weights once with a decode op)
    requires both builders to emit byte-identical weight keys and tags, so
    the scaffolding must never drift between them.

    ``m_rows`` is the activation-row count of every GEMM stage — the slotted
    batch for decode, the padded prompt length for prefill.

    ``ffn_for(l, lp, stages)``, when given, replaces the dense gated-FFN
    emission for layer ``l`` (the MoE builder supplies the router glue +
    per-expert GemmStages); it consumes ``env['h2']`` (set by the post-attn
    glue) and must leave ``env['x']`` updated with the FFN residual. The
    attention scaffolding — weight keys and tags included — stays the
    shared copy, so MoE attention GEMMs coalesce with dense tenants'."""
    hd = cfg.resolved_head_dim
    blocks = params["blocks"]
    # weight identity includes the params object: two tenants of the same
    # architecture only share operands (and thus a single weight load in
    # the superkernel) when they literally serve the same weights
    pid = id(params)

    def glue(fn, reads=None, writes=None):
        stages.append(GlueStage(fn, reads=reads, writes=writes))

    def gemm(tag, wkey, wfn, infn, outfn, n, k, reads, writes):
        stages.append(GemmStage(tag, wkey, wfn, infn, outfn,
                                shape=GemmShape(m=m_rows, n=n, k=k),
                                reads=reads, writes=writes))

    for l in range(cfg.num_layers):
        lp = jax.tree_util.tree_map(lambda a, l=l: a[l], blocks)
        is_global = cfg.layer_is_global(l)

        def pre_attn(env, lp=lp):
            env["h"] = rmsnorm(env["x"], lp["ln1"], cfg.norm_eps)

        glue(pre_attn, reads=("x",), writes=("h",))
        for name, n_heads in (("wq", cfg.num_heads), ("wk", cfg.num_kv_heads),
                              ("wv", cfg.num_kv_heads)):
            gemm(f"attn_{name}", weight_key(cfg.name, pid, name, layer=l),
                 lambda lp=lp, name=name: lp["attn"][name],
                 lambda env: env["h"],
                 lambda env, out, name=name: env.__setitem__(name, out),
                 n_heads * hd, cfg.d_model, ("h",), (name,))

        # the attention glue's read set is phase-specific (decode streams
        # the slotted cache, prefill ropes by env positions) — the caller
        # passes the accurate set via attend_reads
        glue(attend_for(l, lp, is_global), reads=attend_reads,
             writes=("attn_out", "new_layers"))
        gemm("attn_wo", weight_key(cfg.name, pid, "wo", layer=l),
             lambda lp=lp: lp["attn"]["wo"],
             lambda env: env["attn_out"],
             lambda env, out: env.__setitem__("attn_proj", out),
             cfg.d_model, cfg.num_heads * hd, ("attn_out",), ("attn_proj",))

        def post_attn(env, lp=lp):
            env["x"] = env["x"] + env["attn_proj"]
            env["h2"] = rmsnorm(env["x"], lp["ln2"], cfg.norm_eps)

        glue(post_attn, reads=("x", "attn_proj"), writes=("x", "h2"))
        if ffn_for is not None:
            ffn_for(l, lp, stages)
            continue
        gemm("ffn_gate", weight_key(cfg.name, pid, "w_gate", layer=l),
             lambda lp=lp: lp["mlp"]["w_gate"],
             lambda env: env["h2"],
             lambda env, out: env.__setitem__("gate", out),
             cfg.d_ff, cfg.d_model, ("h2",), ("gate",))
        gemm("ffn_up", weight_key(cfg.name, pid, "w_up", layer=l),
             lambda lp=lp: lp["mlp"]["w_up"],
             lambda env: env["h2"],
             lambda env, out: env.__setitem__("up", out),
             cfg.d_ff, cfg.d_model, ("h2",), ("up",))

        def act(env):
            env["act"] = _silu_mul(env["gate"], env["up"])

        glue(act, reads=("gate", "up"), writes=("act",))
        gemm("ffn_down", weight_key(cfg.name, pid, "w_down", layer=l),
             lambda lp=lp: lp["mlp"]["w_down"],
             lambda env: env["act"],
             lambda env, out: env.__setitem__("down", out),
             cfg.d_model, cfg.d_ff, ("act",), ("down",))

        def post_ffn(env):
            env["x"] = env["x"] + env["down"]

        glue(post_ffn, reads=("x", "down"), writes=("x",))


# tied-embedding transposes, memoized per embed-array identity: every
# template of one (model, params) — decode at any batch size, prefill at
# any bucket — must hand out the SAME transposed array object, because the
# dispatch executor's packed-weight cache guards on weight-array identity;
# a per-template transpose would make batch-size alternation or
# prefill/decode interleaving look like a weight hot-swap and repack the
# model's largest matrix every flip. Both the embed and the transpose are
# held WEAKLY: the transpose stays alive exactly as long as some template
# closure references it, so discarding an engine/JIT frees its largest
# matrices instead of a module-level cache pinning them process-wide. The
# embed ref doubles as the id-recycling guard (a dead embed whose id is
# reused can never serve a stale transpose — its ref reads None).
_TIED_UNEMBED: Dict[int, Tuple["weakref.ref", "weakref.ref"]] = {}


def _tied_unembed(params) -> jax.Array:
    embed = params["embed"]
    ent = _TIED_UNEMBED.get(id(embed))
    if ent is not None:
        e, wT = ent[0](), ent[1]()
        if e is embed and wT is not None:
            return wT
    wT = embed.T
    if len(_TIED_UNEMBED) > 64:            # prune dead refs opportunistically
        for k in [k for k, (e, _) in _TIED_UNEMBED.items() if e() is None]:
            del _TIED_UNEMBED[k]
    _TIED_UNEMBED[id(embed)] = (weakref.ref(embed), weakref.ref(wT))
    return wT


def _emit_decode_embed(cfg: ModelConfig, params, stages: List[Stage]) -> None:
    """Token-embedding prologue shared by every DECODE builder (dense/MoE
    via the GQA scaffold, SSM): scaled embed of the step's [B, 1] tokens
    squeezed to [B, d], plus the cache-position snapshot."""

    def embed(env):
        x = params["embed"][env["tokens"]]
        env["x"] = (x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype))[:, 0]
        env["pos"] = env["cache"]["pos"]

    stages.append(GlueStage(embed, reads=("tokens", "cache"),
                            writes=("x", "pos")))


def _emit_final_logits(cfg: ModelConfig, params, stages: List[Stage], *,
                       m_rows: int) -> None:
    """Final-norm + unembed tail shared by every decode builder."""

    def final_norm(env):
        env["hf"] = rmsnorm(env["x"], params["final_norm"], cfg.norm_eps)

    stages.append(GlueStage(final_norm, reads=("x",), writes=("hf",)))
    _emit_unembed(cfg, params, stages, m_rows=m_rows)


def _emit_unembed(cfg: ModelConfig, params, stages: List[Stage], *,
                  m_rows: int) -> None:
    """Emit the unembedding GEMM over ``env['hf']`` into ``env['logits']``
    (shared by both builders; ``m_rows`` = the normed rows to unembed)."""
    pid = id(params)
    if cfg.tie_embeddings:
        # hoisted to template-build time AND shared across templates (see
        # _TIED_UNEMBED above): one O(vocab·d) transpose per params, one
        # stable array identity for the executor's weight guard
        wT = _tied_unembed(params)
        wfn, n = (lambda: wT), int(params["embed"].shape[0])
    else:
        wfn, n = (lambda: params["unembed"]), int(params["unembed"].shape[1])
    stages.append(GemmStage(
        "unembed", weight_key(cfg.name, pid, "unembed"), wfn,
        lambda env: env["hf"],
        lambda env, out: env.__setitem__("logits", out),
        shape=GemmShape(m=m_rows, n=n, k=cfg.d_model),
        reads=("hf",), writes=("logits",)))


def _gqa_decode_attend(cfg: ModelConfig, B: int, q_flat, k_flat, v_flat,
                       kc, vc, pos, is_global: bool, out_dtype
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One layer of single-token slotted-cache GQA attention: the PURE math
    shared verbatim by the per-layer glue (``_decode_attend_for``) and the
    stacked scan body — one copy so the two paths cannot drift. ``kc``/
    ``vc`` are the layer's cache slices [B, Hkv, S, hd]; returns
    (attn_out [B, H·hd], new kc, new vc)."""
    hd = cfg.resolved_head_dim
    q = q_flat.reshape(B, 1, cfg.num_heads, hd)
    k = k_flat.reshape(B, 1, cfg.num_kv_heads, hd)
    v = v_flat.reshape(B, 1, cfg.num_kv_heads, hd)
    posb = pos[:, None]
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    upd = jax.vmap(lambda c, kn, p: jax.lax.dynamic_update_slice(
        c, kn, (0, p, 0)))
    kc = upd(kc, k.transpose(0, 2, 1, 3).astype(kc.dtype), pos)
    vc = upd(vc, v.transpose(0, 2, 1, 3).astype(vc.dtype), pos)
    S = kc.shape[2]
    G = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(B, 1, cfg.num_kv_heads, G, hd)
    scores = jnp.einsum("bshgd,bhtd->bhgst", qg, kc,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    idx = jnp.arange(S)
    ok = idx[None, :] <= pos[:, None]
    if cfg.window_size > 0 and not is_global:
        ok = ok & (idx[None, :] > (pos[:, None] - cfg.window_size))
    scores = jnp.where(ok[:, None, None, None, :], scores, -2.0e38)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bshgd", p, vc.astype(jnp.float32))
    return (o.reshape(B, cfg.num_heads * hd).astype(out_dtype), kc, vc)


# ---------------------------------------------------------------------------
# jitted per-layer glue — the bit-identity bridge to the stacked regime
# ---------------------------------------------------------------------------
# XLA CPU contracts mul→add chains into FMAs (and loop-fuses
# transcendentals) when compiling a jitted program, but not when executing
# the same ops eagerly one by one — so per-layer glue running eager math
# computes different last-ulp bits than the SAME helper inlined in a jitted
# scan body. Standalone-jitting a helper is bitwise identical to inlining
# it in a jitted scan (measured on this backend), so the per-layer (oracle)
# glue calls these memoized jit wrappers instead of the raw helpers: both
# template regimes then execute jit-compiled bits and the
# stacked-vs-per-layer contract is exact token/cache equality.
# ModelConfig/SSMConfig/MoEConfig are frozen dataclasses, so configs key
# the memo by VALUE — two tenants of the same architecture share entries.
_GLUE_JITS: Dict[Tuple, Callable] = {}

# silu(gate) ⊙ up — the gated-FFN activation glue (dense layers and MoE
# per-expert stages). jax.jit traces lazily per (shape, dtype).
_silu_mul = jax.jit(lambda gate, up: jax.nn.silu(gate) * up)


def _jitted_decode_attend(cfg: ModelConfig, B: int, is_global: bool,
                          out_dtype) -> Callable:
    key = ("decode-attend", cfg, B, bool(is_global),
           jnp.dtype(out_dtype).name)
    fn = _GLUE_JITS.get(key)
    if fn is None:
        def attend(q, k, v, kc, vc, pos):
            return _gqa_decode_attend(cfg, B, q, k, v, kc, vc, pos,
                                      is_global, out_dtype)

        fn = _GLUE_JITS[key] = jax.jit(attend)
    return fn


def _jitted_prefill_attend(cfg: ModelConfig, Sp: int, is_global: bool,
                           out_dtype) -> Callable:
    key = ("prefill-attend", cfg, Sp, bool(is_global),
           jnp.dtype(out_dtype).name)
    fn = _GLUE_JITS.get(key)
    if fn is None:
        def attend(q, k, v, positions):
            return _causal_prefill_attend(cfg, Sp, q, k, v, positions,
                                          is_global, out_dtype)

        fn = _GLUE_JITS[key] = jax.jit(attend)
    return fn


def _jitted_moe_route(cfg: ModelConfig, B: int, C: int) -> Callable:
    from repro.models import moe as moe_lib
    mcfg = cfg.moe
    key = ("moe-route", cfg, B, C)
    fn = _GLUE_JITS.get(key)
    if fn is None:
        E, top_k, d = mcfg.num_experts, mcfg.top_k, cfg.d_model

        def route_dispatch(router_p, h2):
            weights, experts, _aux = moe_lib.route(router_p, h2, mcfg)
            xg = h2.reshape(1, B, d)
            wgt = weights.reshape(1, B, top_k)
            eg = experts.reshape(1, B, top_k)
            buf, meta = jax.vmap(
                lambda xx, ww, ee: moe_lib.dispatch_tokens(
                    xx, ww, ee, E, top_k, C))(xg, wgt, eg)
            return buf, meta, wgt

        fn = _GLUE_JITS[key] = jax.jit(route_dispatch)
    return fn


def _jitted_moe_combine(cfg: ModelConfig, B: int) -> Callable:
    from repro.models import moe as moe_lib
    key = ("moe-combine", cfg, B)
    fn = _GLUE_JITS.get(key)
    if fn is None:
        d = cfg.d_model

        def combine(out_buf, wgt, meta):
            return jax.vmap(
                lambda ob, ww, mm: moe_lib.combine_tokens(
                    ob, ww.reshape(-1), mm, B, d))(out_buf, wgt, meta)

        fn = _GLUE_JITS[key] = jax.jit(combine)
    return fn


def _jitted_ssm_core(cfg: ModelConfig) -> Callable:
    from repro.models import ssm as ssm_lib
    key = ("ssm-core", cfg)
    fn = _GLUE_JITS.get(key)
    if fn is None:
        scfg, d = cfg.ssm, cfg.d_model

        def core(mamba_p, zxbcdt, conv, h):
            return ssm_lib.decode_core(mamba_p, zxbcdt,
                                       {"conv": conv, "h": h}, scfg, d)

        fn = _GLUE_JITS[key] = jax.jit(core)
    return fn


def _decode_attend_for(cfg: ModelConfig, B: int):
    """Single-token slotted-cache attention glue factory, shared by the
    dense and MoE decode builders (MoE layers keep standard GQA attention,
    so both families must stay byte-identical here)."""

    def attend_for(l, lp, is_global):
        # one new token per row against the slotted cache, per-row positions
        def attend(env, l=l, is_global=is_global):
            cache = env["cache"]
            pos = jnp.broadcast_to(jnp.asarray(cache["pos"]), (B,))
            attn_out, kc, vc = _jitted_decode_attend(
                cfg, B, is_global, env["h"].dtype)(
                env["wq"], env["wk"], env["wv"],
                cache["layers"]["k"][l], cache["layers"]["v"][l], pos)
            env["new_layers"]["k"].append(kc)
            env["new_layers"]["v"].append(vc)
            env["attn_out"] = attn_out

        return attend

    return attend_for


def _stacked_dense_body_stage(model, params, B: int, lo: int, hi: int, *,
                              moe: bool = False) -> StackedGemmStage:
    """ONE scanned decode body covering layers [lo, hi) of a GQA model —
    the stacked replacement for ~6·Lsub (dense) or (4+3·E)·Lsub (MoE)
    per-layer stages. The scan body replays the per-layer math exactly:
    ``_scan_gemm`` for every projection (replicating the executor's solo
    dispatch), ``_gqa_decode_attend`` for attention, and the literal
    ``moe_lib`` route/dispatch/combine calls for the MoE FFN."""
    cfg: ModelConfig = model.cfg
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    eps = cfg.norm_eps
    blocks = params["blocks"]
    pid = id(params)
    Lsub = hi - lo
    is_global = bool(cfg.layer_is_global(lo))

    def sop(tag, name, arr, m, n, k, layers=Lsub):
        return StackedOperand(
            tag, weight_key(cfg.name, pid, name, stack=(lo, hi)),
            GemmShape(m=m, n=n, k=k, layers=layers),
            lambda a=arr: _stack_slice(a, lo, hi), (arr,))

    attn = blocks["attn"]
    operands = [
        sop("attn_wq", "wq", attn["wq"], B, cfg.num_heads * hd, d),
        sop("attn_wk", "wk", attn["wk"], B, cfg.num_kv_heads * hd, d),
        sop("attn_wv", "wv", attn["wv"], B, cfg.num_kv_heads * hd, d),
        sop("attn_wo", "wo", attn["wo"], B, d, cfg.num_heads * hd),
    ]
    if moe:
        from repro.models import moe as moe_lib
        mcfg = cfg.moe
        E, top_k = mcfg.num_experts, mcfg.top_k
        C = moe_lib.capacity(B, mcfg)
        mp = blocks["moe"]
        # expert packs keep the "expert_*" tags (clustering.is_expert_op
        # detects them through op.stack); layers = Lsub·E waves because
        # each scan step runs E per-expert GEMMs sequentially
        operands += [
            sop("expert_gate", "w_gate", mp["w_gate"], C, cfg.d_ff, d,
                Lsub * E),
            sop("expert_up", "w_up", mp["w_up"], C, cfg.d_ff, d, Lsub * E),
            sop("expert_down", "w_down", mp["w_down"], C, d, cfg.d_ff,
                Lsub * E),
        ]
        routers = _stack_slice(mp["router"], lo, hi)
    else:
        mlp = blocks["mlp"]
        operands += [
            sop("ffn_gate", "w_gate", mlp["w_gate"], B, cfg.d_ff, d),
            sop("ffn_up", "w_up", mlp["w_up"], B, cfg.d_ff, d),
            sop("ffn_down", "w_down", mlp["w_down"], B, d, cfg.d_ff),
        ]
    ln1s = _stack_slice(blocks["ln1"], lo, hi)
    ln2s = _stack_slice(blocks["ln2"], lo, hi)
    # one jitted scan per executor block signature, memoized for the
    # template's lifetime (templates live in the JIT's plan cache, so the
    # steady state reuses one compiled executable)
    jits: Dict[Tuple, Callable] = {}

    def make_scan(bm: int, bn: int, bk: int, interpret: bool):
        def gemm(a, w, n):
            return _scan_gemm(a, w, n, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)

        # every per-layer param enters as a jit ARGUMENT (via xs), never a
        # closure: XLA codegens array CONSTANTS differently than traced
        # arguments in the last ulp (measured on decode_core's einsum
        # chain), and the per-layer oracle's jitted glue receives the same
        # arrays as arguments — bit-identity requires matching regimes
        def scan_fn(x, pos_in, kc_full, vc_full, w, aux):
            pos = jnp.broadcast_to(pos_in, (B,))

            def body(carry, per):
                wl = per["w"]
                h = rmsnorm(carry, per["ln1"], eps)
                q = gemm(h, wl["attn_wq"], cfg.num_heads * hd)
                k = gemm(h, wl["attn_wk"], cfg.num_kv_heads * hd)
                v = gemm(h, wl["attn_wv"], cfg.num_kv_heads * hd)
                attn_out, kc_new, vc_new = _gqa_decode_attend(
                    cfg, B, q, k, v, per["kc"], per["vc"], pos, is_global,
                    h.dtype)
                x2 = carry + gemm(attn_out, wl["attn_wo"], d)
                h2 = rmsnorm(x2, per["ln2"], eps)
                if moe:
                    weights, experts, _aux = moe_lib.route(
                        per["router"], h2, mcfg)
                    xg = h2.reshape(1, B, d)
                    wgt = weights.reshape(1, B, top_k)
                    eg = experts.reshape(1, B, top_k)
                    buf, meta = jax.vmap(
                        lambda xx, ww, ee: moe_lib.dispatch_tokens(
                            xx, ww, ee, E, top_k, C))(xg, wgt, eg)
                    downs = []
                    for e in range(E):
                        ge = gemm(buf[0, e], wl["expert_gate"][e], cfg.d_ff)
                        ue = gemm(buf[0, e], wl["expert_up"][e], cfg.d_ff)
                        downs.append(gemm(jax.nn.silu(ge) * ue,
                                          wl["expert_down"][e], d))
                    out_buf = jnp.stack(downs, axis=0)[None]
                    y = jax.vmap(
                        lambda ob, ww, mm: moe_lib.combine_tokens(
                            ob, ww.reshape(-1), mm, B, d))(out_buf, wgt,
                                                           meta)
                    x3 = x2 + y.reshape(B, d).astype(h2.dtype)
                else:
                    gate = gemm(h2, wl["ffn_gate"], cfg.d_ff)
                    up = gemm(h2, wl["ffn_up"], cfg.d_ff)
                    x3 = x2 + gemm(jax.nn.silu(gate) * up, wl["ffn_down"],
                                   d)
                return x3, (kc_new, vc_new)

            xs = dict(aux, kc=kc_full[lo:hi], vc=vc_full[lo:hi], w=w)
            return jax.lax.scan(body, x, xs)

        return scan_fn

    aux = {"ln1": ln1s, "ln2": ln2s}
    if moe:
        aux["router"] = routers

    def run(env, padded, ex, block=None):
        # live-tuned tile override (JitSession._run_stacked): keyed beside
        # the executor defaults, so each distinct tuned config compiles
        # its scan body once and stable configs never retrace
        key = (ex.bm, ex.bn, ex.bk, ex.interpret) if block is None else \
            (block.bm, block.bn, block.bk, ex.interpret)
        fn = jits.get(key)
        if fn is None:
            fn = jits[key] = jax.jit(make_scan(*key))
        cache = env["cache"]
        x, (kc_new, vc_new) = fn(env["x"], jnp.asarray(cache["pos"]),
                                 cache["layers"]["k"], cache["layers"]["v"],
                                 padded, aux)
        env["x"] = x
        env["new_layers"]["k"].append(kc_new)
        env["new_layers"]["v"].append(vc_new)

    return StackedGemmStage(
        tag=f"body_{lo}_{hi}",
        weight_key=weight_key(cfg.name, pid, "body", stack=(lo, hi)),
        operands=operands, layers=Lsub, run=run,
        reads=("x", "cache"), writes=("x", "new_layers"))


def _build_stacked_gqa_decode_template(model, params, batch: int, *,
                                       moe: bool = False) -> ProgramTemplate:
    """Stacked counterpart of ``_build_gqa_decode_template``: one scanned
    body stage per homogeneous sub-stack instead of per-layer emission.
    The epilogue concatenates the bodies' [Lsub, ...] cache updates —
    the same [L, ...] layout the per-layer path's ``jnp.stack`` built."""
    cfg: ModelConfig = model.cfg
    stages: List[Stage] = []
    _emit_decode_embed(cfg, params, stages)
    for lo, hi in partition_layers(cfg.global_layer_flags()):
        stages.append(_stacked_dense_body_stage(model, params, batch,
                                                lo, hi, moe=moe))
    _emit_final_logits(cfg, params, stages, m_rows=batch)

    def finish(env):
        cache = env["cache"]
        env["cache"] = {
            "pos": cache["pos"] + 1,
            "layers": {
                "k": jnp.concatenate(env["new_layers"]["k"], axis=0),
                "v": jnp.concatenate(env["new_layers"]["v"], axis=0),
            },
        }

    stages.append(GlueStage(finish, reads=("cache", "new_layers"),
                            writes=("cache",)))
    return ProgramTemplate(stages=stages, batch=batch, model_name=cfg.name)


def _build_gqa_decode_template(model, params, batch: int, *,
                               ffn_for=None) -> ProgramTemplate:
    """Shared decode-template scaffold for every GQA-attention family:
    embed glue, the per-layer attention + FFN body (``ffn_for`` swaps the
    dense gated FFN for a family-specific emitter — MoE), final norm,
    unembed and the KV-cache write-back epilogue."""
    cfg: ModelConfig = model.cfg
    B = batch
    stages: List[Stage] = []

    _emit_decode_embed(cfg, params, stages)
    _emit_dense_body(cfg, params, stages, m_rows=B,
                     attend_for=_decode_attend_for(cfg, B), ffn_for=ffn_for)
    _emit_final_logits(cfg, params, stages, m_rows=B)

    def finish(env):
        cache = env["cache"]
        env["cache"] = {
            "pos": cache["pos"] + 1,
            "layers": {
                "k": jnp.stack(env["new_layers"]["k"]),
                "v": jnp.stack(env["new_layers"]["v"]),
            },
        }

    stages.append(GlueStage(finish, reads=("cache", "new_layers"),
                            writes=("cache",)))
    return ProgramTemplate(stages=stages, batch=B, model_name=cfg.name)


def build_dense_decode_template(model, params, batch: int, *,
                                stacked: bool = True) -> ProgramTemplate:
    """Compile the decode step of a dense GQA model into a ProgramTemplate.

    Equivalent to ``Model.decode_step`` but with every projection GEMM
    declared to the JIT. Supported: arch_type 'dense' (and the text path of
    'vlm'). Per-step inputs (tokens [B, 1], KV cache) are read from the
    bound program's env, so one template serves every steady-state step.

    ``stacked=True`` (default) emits one scanned body per homogeneous
    layer sub-stack — O(1)-in-depth build; ``stacked=False`` keeps the
    per-layer emission (the bit-identity oracle).
    """
    assert model.cfg.arch_type in ("dense", "vlm"), model.cfg.arch_type
    if stacked:
        return _build_stacked_gqa_decode_template(model, params, batch)
    return _build_gqa_decode_template(model, params, batch)


# ---------------------------------------------------------------------------
# non-dense decode programs: MoE and SSM tenants as first-class streams
# ---------------------------------------------------------------------------

def moe_program_cache_key(model, params, batch: int, cache, *,
                          stacked: bool = True) -> Tuple:
    """Plan-cache key for an MoE decode template. Same discipline as
    ``dense_program_cache_key`` (params identity lives in the lookup-site
    guard, not the key); the expert capacity C is a pure function of
    (batch, cfg.moe), both captured here via batch + model identity."""
    kc = cache["layers"]["k"]
    return ("moe-decode", model.cfg.name, id(model), batch,
            str(params["embed"].dtype), str(kc.dtype), tuple(kc.shape),
            ("stacked", bool(stacked), model.cfg.num_layers))


def build_moe_decode_template(model, params, batch: int, *,
                              stacked: bool = True) -> ProgramTemplate:
    """Compile the decode step of an MoE model into a ProgramTemplate.

    ``stacked=True`` (default) emits one scanned body per homogeneous
    sub-stack — the router/dispatch/combine glue runs INSIDE the scan body
    and the 3 expert packs become [Lsub, E, k, n] stacked operands;
    ``stacked=False`` keeps the per-layer 3·E-GemmStage emission below
    (the bit-identity oracle).

    Equivalent to ``Model.decode_step`` for arch_type 'moe': the attention
    scaffolding is the SAME emission as the dense builder (so MoE attention
    GEMMs coalesce with dense tenants'), while each layer's FFN becomes

      * a glue stage running the router + sort-based capacity dispatch
        (``moe_lib.route`` / ``dispatch_tokens`` — literally the code
        ``moe_ffn`` runs, so capacity/drop semantics cannot drift), then
      * 3·E declared per-expert ``GemmStage``s (gate/up/down over the
        [C, d] expert buffer) tagged ``expert_*`` with the expert index in
        the weight key — so the same expert's GEMMs share operands across
        tenants serving the same params, and coalesce with any tenant's
        GEMMs sharing their (n, k) (a dense FFN with the same d_ff does),
      * a combine glue scattering the weighted expert outputs back.

    Expert weight slices are materialized ONCE here at build time
    (``moe_lib.expert_ffn_weights``) and closed over, giving the dispatch
    executor's packed-weight cache stable array identities — a fresh slice
    per step would read as a phantom hot-swap and repack every tick.

    Within one program the expert GEMMs execute in program order (one live
    op per stream); the cross-tenant coalescing is the point
    (``JitStats.expert_coalesced``).
    """
    cfg: ModelConfig = model.cfg
    assert cfg.arch_type == "moe" and cfg.has_moe, cfg.arch_type
    if stacked:
        return _build_stacked_gqa_decode_template(model, params, batch,
                                                  moe=True)
    from repro.models import moe as moe_lib
    mcfg = cfg.moe
    B, d = batch, cfg.d_model
    E, top_k = mcfg.num_experts, mcfg.top_k
    # decode routes the step's B tokens as one group (moe_ffn's G=1 path)
    C = moe_lib.capacity(B, mcfg)
    pid = id(params)

    def ffn_for(l, lp, stages):
        moe_p = lp["moe"]
        sliced = [moe_lib.expert_ffn_weights(moe_p, e) for e in range(E)]

        def glue(fn, reads=None, writes=None):
            stages.append(GlueStage(fn, reads=reads, writes=writes))

        def route_dispatch(env, moe_p=moe_p):
            buf, meta, wgt = _jitted_moe_route(cfg, B, C)(
                moe_p["router"], env["h2"])
            env["moe_buf"], env["moe_meta"] = buf, meta
            env["moe_w"] = wgt
            env["moe_down"] = [None] * E

        glue(route_dispatch, reads=("h2",),
             writes=("moe_buf", "moe_meta", "moe_w", "moe_down"))
        for e in range(E):
            wg, wu, wd = sliced[e]
            stages.append(GemmStage(
                "expert_gate",
                weight_key(cfg.name, pid, "w_gate", layer=l, expert=e),
                lambda w=wg: w,
                lambda env, e=e: env["moe_buf"][0, e],
                lambda env, out, e=e: env.__setitem__(("moe_gate", e), out),
                shape=GemmShape(m=C, n=cfg.d_ff, k=d),
                reads=("moe_buf",), writes=(("moe_gate", e),)))
            stages.append(GemmStage(
                "expert_up",
                weight_key(cfg.name, pid, "w_up", layer=l, expert=e),
                lambda w=wu: w,
                lambda env, e=e: env["moe_buf"][0, e],
                lambda env, out, e=e: env.__setitem__(("moe_up", e), out),
                shape=GemmShape(m=C, n=cfg.d_ff, k=d),
                reads=("moe_buf",), writes=(("moe_up", e),)))

            def act(env, e=e):
                env[("moe_act", e)] = _silu_mul(env.pop(("moe_gate", e)),
                                                env.pop(("moe_up", e)))

            glue(act, reads=(("moe_gate", e), ("moe_up", e)),
                 writes=(("moe_act", e),))
            stages.append(GemmStage(
                "expert_down",
                weight_key(cfg.name, pid, "w_down", layer=l, expert=e),
                lambda w=wd: w,
                lambda env, e=e: env[("moe_act", e)],
                lambda env, out, e=e: env["moe_down"].__setitem__(e, out),
                shape=GemmShape(m=C, n=d, k=cfg.d_ff),
                reads=(("moe_act", e),), writes=("moe_down",)))

        def combine(env):
            out_buf = jnp.stack(env.pop("moe_down"), axis=0)[None]
            y = _jitted_moe_combine(cfg, B)(out_buf, env.pop("moe_w"),
                                            env.pop("moe_meta"))
            env.pop("moe_buf")
            env["x"] = env["x"] + y.reshape(B, d).astype(env["h2"].dtype)

        glue(combine, reads=("moe_down", "moe_w", "moe_meta", "moe_buf",
                             "x", "h2"),
             writes=("x",))

    return _build_gqa_decode_template(model, params, batch, ffn_for=ffn_for)


def ssm_program_cache_key(model, params, batch: int, cache, *,
                          stacked: bool = True) -> Tuple:
    """Plan-cache key for an SSM decode template: (model identity, batch,
    dtype, recurrent-cache geometry). Guard discipline as for dense."""
    cc = cache["layers"]["conv"]
    return ("ssm-decode", model.cfg.name, id(model), batch,
            str(params["embed"].dtype), str(cc.dtype), tuple(cc.shape),
            tuple(cache["layers"]["h"].shape),
            ("stacked", bool(stacked), model.cfg.num_layers))


def _build_stacked_ssm_decode_template(model, params, batch: int
                                       ) -> ProgramTemplate:
    """Stacked counterpart of the per-layer SSM builder: the whole
    attention-free stack is ONE homogeneous sub-stack, so a single scanned
    body stage declares the stacked in/out projections and runs the
    selective-scan recurrence (``ssm_lib.decode_core`` — the same single
    copy of the math) inside the scan body."""
    cfg: ModelConfig = model.cfg
    from repro.models import ssm as ssm_lib
    scfg = cfg.ssm
    B, d = batch, cfg.d_model
    d_inner = scfg.expand * d
    n_in = 2 * d_inner + 2 * scfg.d_state + scfg.num_heads(d)
    eps = cfg.norm_eps
    blocks = params["blocks"]
    mamba = blocks["mamba"]
    pid = id(params)
    L = cfg.num_layers
    lo, hi = 0, L
    stages: List[Stage] = []
    _emit_decode_embed(cfg, params, stages)

    def reset_layers(env):
        env["new_layers"] = {"conv": [], "h": []}

    stages.append(GlueStage(reset_layers, reads=(), writes=("new_layers",)))
    operands = [
        StackedOperand(
            "ssm_in_proj", weight_key(cfg.name, pid, "in_proj",
                                      stack=(lo, hi)),
            GemmShape(m=B, n=n_in, k=d, layers=L),
            lambda: mamba["in_proj"], (mamba["in_proj"],)),
        StackedOperand(
            "ssm_out_proj", weight_key(cfg.name, pid, "out_proj",
                                       stack=(lo, hi)),
            GemmShape(m=B, n=d, k=d_inner, layers=L),
            lambda: mamba["out_proj"], (mamba["out_proj"],)),
    ]
    # decode_core reads only the conv/dt/A/D/norm leaves; the projections
    # are the declared stacked operands above
    mamba_rest = {k: v for k, v in mamba.items()
                  if k not in ("in_proj", "out_proj")}
    ln1s = blocks["ln1"]
    jits: Dict[Tuple, Callable] = {}

    def make_scan(bm: int, bn: int, bk: int, interpret: bool):
        def gemm(a, w, n):
            return _scan_gemm(a, w, n, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)

        # per-layer params enter as jit ARGUMENTS (xs), not closures — XLA
        # codegens embedded constants differently in the last ulp than
        # traced arguments, which would break bit-identity with the
        # per-layer oracle's jitted decode_core glue
        def scan_fn(x, conv_full, h_full, w, aux):
            def body(carry, per):
                hh = rmsnorm(carry, per["ln1"], eps)
                zxbcdt = gemm(hh, per["w"]["ssm_in_proj"], n_in)
                y, new_c = ssm_lib.decode_core(
                    per["mamba"], zxbcdt,
                    {"conv": per["conv"], "h": per["h"]}, scfg, d)
                out = gemm(y, per["w"]["ssm_out_proj"], d)
                return carry + out, (new_c["conv"], new_c["h"])

            xs = dict(aux, conv=conv_full, h=h_full, w=w)
            return jax.lax.scan(body, x, xs)

        return scan_fn

    aux = {"ln1": ln1s, "mamba": mamba_rest}

    def run(env, padded, ex, block=None):
        # live-tuned tile override (JitSession._run_stacked): keyed beside
        # the executor defaults, so each distinct tuned config compiles
        # its scan body once and stable configs never retrace
        key = (ex.bm, ex.bn, ex.bk, ex.interpret) if block is None else \
            (block.bm, block.bn, block.bk, ex.interpret)
        fn = jits.get(key)
        if fn is None:
            fn = jits[key] = jax.jit(make_scan(*key))
        cache = env["cache"]
        x, (conv_new, h_new) = fn(env["x"], cache["layers"]["conv"],
                                  cache["layers"]["h"], padded, aux)
        env["x"] = x
        env["new_layers"]["conv"].append(conv_new)
        env["new_layers"]["h"].append(h_new)

    stages.append(StackedGemmStage(
        tag=f"body_{lo}_{hi}",
        weight_key=weight_key(cfg.name, pid, "body", stack=(lo, hi)),
        operands=operands, layers=L, run=run,
        reads=("x", "cache"), writes=("x", "new_layers")))
    _emit_final_logits(cfg, params, stages, m_rows=B)

    def finish(env):
        cache = env["cache"]
        env["cache"] = {
            "pos": cache["pos"] + 1,
            "layers": {
                "conv": jnp.concatenate(env["new_layers"]["conv"], axis=0),
                "h": jnp.concatenate(env["new_layers"]["h"], axis=0),
            },
        }

    stages.append(GlueStage(finish, reads=("cache", "new_layers"),
                            writes=("cache",)))
    return ProgramTemplate(stages=stages, batch=B, model_name=cfg.name)


def build_ssm_decode_template(model, params, batch: int, *,
                              stacked: bool = True) -> ProgramTemplate:
    """Compile the decode step of an attention-free SSM (Mamba-2/SSD) model
    into a ProgramTemplate. Equivalent to ``Model.decode_step`` for
    arch_type 'ssm': per layer, the in projection ([B, d] → z/xBC/dt) and
    the out projection are declared ``GemmStage``s — coalescible across
    tenants — while the selective-scan recurrence between them runs as glue
    via ``ssm_lib.decode_core`` (the SAME function ``ssd_decode_step``
    calls, so the recurrence math has exactly one copy). The epilogue
    stacks the per-layer conv windows + SSD states back into the tenant's
    recurrent cache.
    """
    cfg: ModelConfig = model.cfg
    assert cfg.arch_type == "ssm" and cfg.has_ssm, cfg.arch_type
    if stacked:
        return _build_stacked_ssm_decode_template(model, params, batch)
    from repro.models import ssm as ssm_lib
    scfg = cfg.ssm
    B, d = batch, cfg.d_model
    d_inner = scfg.expand * d
    n_in = 2 * d_inner + 2 * scfg.d_state + scfg.num_heads(d)
    blocks = params["blocks"]
    pid = id(params)
    stages: List[Stage] = []

    def glue(fn, reads=None, writes=None):
        stages.append(GlueStage(fn, reads=reads, writes=writes))

    _emit_decode_embed(cfg, params, stages)

    def reset_layers(env):
        env["new_layers"] = {"conv": [], "h": []}

    glue(reset_layers, reads=(), writes=("new_layers",))
    for l in range(cfg.num_layers):
        lp = jax.tree_util.tree_map(lambda a, l=l: a[l], blocks)

        def pre(env, lp=lp):
            env["h"] = rmsnorm(env["x"], lp["ln1"], cfg.norm_eps)

        glue(pre, reads=("x",), writes=("h",))
        stages.append(GemmStage(
            "ssm_in_proj", weight_key(cfg.name, pid, "in_proj", layer=l),
            lambda lp=lp: lp["mamba"]["in_proj"],
            lambda env: env["h"],
            lambda env, out: env.__setitem__("zxbcdt", out),
            shape=GemmShape(m=B, n=n_in, k=d),
            reads=("h",), writes=("zxbcdt",)))

        def scan(env, lp=lp, l=l):
            layers = env["cache"]["layers"]
            y, new_c = _jitted_ssm_core(cfg)(
                lp["mamba"], env.pop("zxbcdt"),
                layers["conv"][l], layers["h"][l])
            env["new_layers"]["conv"].append(new_c["conv"])
            env["new_layers"]["h"].append(new_c["h"])
            env["ssm_y"] = y

        glue(scan, reads=("cache", "zxbcdt"),
             writes=("new_layers", "ssm_y"))
        stages.append(GemmStage(
            "ssm_out_proj", weight_key(cfg.name, pid, "out_proj", layer=l),
            lambda lp=lp: lp["mamba"]["out_proj"],
            lambda env: env["ssm_y"],
            lambda env, out: env.__setitem__("x", env["x"] + out),
            shape=GemmShape(m=B, n=d, k=d_inner),
            reads=("ssm_y", "x"), writes=("x",)))

    _emit_final_logits(cfg, params, stages, m_rows=B)

    def finish(env):
        cache = env["cache"]
        env["cache"] = {
            "pos": cache["pos"] + 1,
            "layers": {
                "conv": jnp.stack(env["new_layers"]["conv"]),
                "h": jnp.stack(env["new_layers"]["h"]),
            },
        }

    glue(finish, reads=("cache", "new_layers"), writes=("cache",))
    return ProgramTemplate(stages=stages, batch=B, model_name=cfg.name)


# ---------------------------------------------------------------------------
# prefill programs — the prompt pass as first-class declared ops
# ---------------------------------------------------------------------------

def prefill_bucket(prompt_len: int, minimum: int = 8) -> int:
    """Power-of-two padding bucket for a prompt length.

    Prefill templates are compiled per bucket, not per exact length, so the
    plan-cache key space stays finite over arbitrary prompt distributions.
    Padded tail rows are computed and discarded — causal masking keeps them
    out of every real row's softmax, and the epilogue copies only the real
    positions into the KV cache — so any bucket ≥ prompt_len is correct.
    """
    assert prompt_len >= 1, prompt_len
    return max(minimum, 1 << (prompt_len - 1).bit_length())


def _causal_prefill_attend(cfg: ModelConfig, Sp: int, q_flat, k_flat,
                           v_flat, positions, is_global: bool, out_dtype
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One layer of causal prompt attention: the PURE math shared verbatim
    by the per-layer prefill glue and the stacked scan body. Returns
    (attn_out [Sp, H·hd], k [1, Hkv, Sp, hd] rope'd, v [1, Hkv, Sp, hd]
    raw) — the k/v pair in decode-cache layout, exactly what
    transformer._project_kv emits for the analytic path."""
    hd = cfg.resolved_head_dim
    q = q_flat.reshape(1, Sp, cfg.num_heads, hd)
    k = k_flat.reshape(1, Sp, cfg.num_kv_heads, hd)
    v = v_flat.reshape(1, Sp, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    G = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(1, Sp, cfg.num_kv_heads, G, hd)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    idx = jnp.arange(Sp)
    ok = idx[None, :] <= idx[:, None]
    if cfg.window_size > 0 and not is_global:
        ok = ok & (idx[None, :] > (idx[:, None] - cfg.window_size))
    scores = jnp.where(ok[None, None, None], scores, -2.0e38)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return (o.reshape(Sp, cfg.num_heads * hd).astype(out_dtype),
            k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))


def _stacked_prefill_body_stage(model, params, Sp: int, lo: int, hi: int
                                ) -> StackedGemmStage:
    """ONE scanned prefill body covering layers [lo, hi): the stacked
    replacement for the per-layer prompt-pass stages. The scan body replays
    ``_causal_prefill_attend`` verbatim and stacks each layer's [1, Hkv,
    Sp, hd] KV pair into a [Lsub, Hkv, Sp, hd] ys chunk — the layout the
    shared prefill epilogue already concatenates."""
    cfg: ModelConfig = model.cfg
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    eps = cfg.norm_eps
    blocks = params["blocks"]
    pid = id(params)
    Lsub = hi - lo
    is_global = bool(cfg.layer_is_global(lo))

    def sop(tag, name, arr, n, k):
        return StackedOperand(
            tag, weight_key(cfg.name, pid, name, stack=(lo, hi)),
            GemmShape(m=Sp, n=n, k=k, layers=Lsub),
            lambda a=arr: _stack_slice(a, lo, hi), (arr,))

    attn = blocks["attn"]
    mlp = blocks["mlp"]
    operands = [
        sop("attn_wq", "wq", attn["wq"], cfg.num_heads * hd, d),
        sop("attn_wk", "wk", attn["wk"], cfg.num_kv_heads * hd, d),
        sop("attn_wv", "wv", attn["wv"], cfg.num_kv_heads * hd, d),
        sop("attn_wo", "wo", attn["wo"], d, cfg.num_heads * hd),
        sop("ffn_gate", "w_gate", mlp["w_gate"], cfg.d_ff, d),
        sop("ffn_up", "w_up", mlp["w_up"], cfg.d_ff, d),
        sop("ffn_down", "w_down", mlp["w_down"], d, cfg.d_ff),
    ]
    ln1s = _stack_slice(blocks["ln1"], lo, hi)
    ln2s = _stack_slice(blocks["ln2"], lo, hi)
    jits: Dict[Tuple, Callable] = {}

    def make_scan(bm: int, bn: int, bk: int, interpret: bool):
        def gemm(a, w, n):
            return _scan_gemm(a, w, n, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)

        # per-layer norms enter as jit arguments (see the decode body note)
        def scan_fn(x, positions, w, aux):
            def body(carry, per):
                wl = per["w"]
                h = rmsnorm(carry, per["ln1"], eps)
                q = gemm(h, wl["attn_wq"], cfg.num_heads * hd)
                k = gemm(h, wl["attn_wk"], cfg.num_kv_heads * hd)
                v = gemm(h, wl["attn_wv"], cfg.num_kv_heads * hd)
                attn_out, k_t, v_t = _causal_prefill_attend(
                    cfg, Sp, q, k, v, positions, is_global, h.dtype)
                x2 = carry + gemm(attn_out, wl["attn_wo"], d)
                h2 = rmsnorm(x2, per["ln2"], eps)
                gate = gemm(h2, wl["ffn_gate"], cfg.d_ff)
                up = gemm(h2, wl["ffn_up"], cfg.d_ff)
                x3 = x2 + gemm(jax.nn.silu(gate) * up, wl["ffn_down"], d)
                return x3, (k_t[0], v_t[0])

            xs = dict(aux, w=w)
            return jax.lax.scan(body, x, xs)

        return scan_fn

    aux = {"ln1": ln1s, "ln2": ln2s}

    def run(env, padded, ex, block=None):
        # live-tuned tile override (JitSession._run_stacked): keyed beside
        # the executor defaults, so each distinct tuned config compiles
        # its scan body once and stable configs never retrace
        key = (ex.bm, ex.bn, ex.bk, ex.interpret) if block is None else \
            (block.bm, block.bn, block.bk, ex.interpret)
        fn = jits.get(key)
        if fn is None:
            fn = jits[key] = jax.jit(make_scan(*key))
        x, (k_ys, v_ys) = fn(env["x"], env["positions"], padded, aux)
        env["x"] = x
        env["new_layers"]["k"].append(k_ys)
        env["new_layers"]["v"].append(v_ys)

    return StackedGemmStage(
        tag=f"body_{lo}_{hi}",
        weight_key=weight_key(cfg.name, pid, "body", stack=(lo, hi)),
        operands=operands, layers=Lsub, run=run,
        reads=("x", "positions"), writes=("x", "new_layers"))


def prefill_program_cache_key(model, params, seq_len: int, cache, *,
                              stacked: bool = True) -> Tuple:
    """Plan-cache key for a dense prefill template: (model identity, padded
    prompt bucket, dtype, cache geometry). Same guard discipline as
    ``dense_program_cache_key`` — params identity is caught by the lookup
    site's ``guard=(model, params)``, never baked into the key."""
    kc = cache["layers"]["k"]
    return ("dense-prefill", model.cfg.name, id(model), seq_len,
            str(params["embed"].dtype), str(kc.dtype), tuple(kc.shape),
            ("stacked", bool(stacked), model.cfg.num_layers))


def build_dense_prefill_template(model, params, seq_len: int, *,
                                 stacked: bool = True) -> ProgramTemplate:
    """Compile the PROMPT pass of a dense GQA model into a ProgramTemplate.

    Every projection GEMM is declared to the JIT with m = ``seq_len`` (the
    padded prefill bucket) — tall problems that enter the live op pool and
    coalesce with decode GEMVs (and other tenants' prefill GEMMs) sharing
    their (n, k) weight dims. Equivalent to ``Model.prefill`` for arch_type
    'dense', last-position logits only.

    Per-request env entries (bound via ``ProgramTemplate.bind``'s
    ``env_extra``):

      * ``tokens``   — the prompt zero-padded to [1, seq_len];
      * ``real_len`` — the true prompt length S (≤ seq_len);
      * ``slot``     — the reserved decode-slot index the epilogue writes
        the request's KV rows + pos into, or None for a single-token
        request that never decodes (the cache is left untouched);
      * ``cache``    — the tenant's slotted decode cache.

    The epilogue writes exactly the rows the engine's analytic admission
    writes (zero-padded to cache_len past S), so a declared prefill is
    bit-compatible with ``ServingEngine._admit``'s cache state.
    """
    cfg: ModelConfig = model.cfg
    assert cfg.arch_type == "dense", cfg.arch_type
    hd = cfg.resolved_head_dim
    Sp = seq_len
    stages: List[Stage] = []

    def glue(fn, reads=None, writes=None):
        stages.append(GlueStage(fn, reads=reads, writes=writes))

    def embed(env):
        x = params["embed"][env["tokens"]]            # [1, Sp, d]
        env["x"] = (x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype))[0]
        env["positions"] = jnp.arange(Sp)[None, :]    # rope positions

    glue(embed, reads=("tokens",), writes=("x", "positions"))

    if stacked:
        for lo, hi in partition_layers(cfg.global_layer_flags()):
            stages.append(_stacked_prefill_body_stage(model, params, Sp,
                                                      lo, hi))
    else:
        def attend_for(l, lp, is_global):
            # causal self-attention over the whole (padded) prompt
            def attend(env, is_global=is_global):
                attn_out, k_t, v_t = _jitted_prefill_attend(
                    cfg, Sp, is_global, env["h"].dtype)(
                    env["wq"], env["wk"], env["wv"], env["positions"])
                env["new_layers"]["k"].append(k_t)
                env["new_layers"]["v"].append(v_t)
                env["attn_out"] = attn_out

            return attend

        # prefill attention never touches the live cache: k/v come from
        # the projections and rope by env positions, landing in new_layers
        _emit_dense_body(cfg, params, stages, m_rows=Sp,
                         attend_for=attend_for,
                         attend_reads=("wq", "wk", "wv", "positions"))

    def final_norm(env):
        # only the last REAL position is unembedded (Model.prefill returns
        # logits for y[:, -1:]); padded tail rows are dropped here
        last = env["x"][env["real_len"] - 1:env["real_len"]]
        env["hf"] = rmsnorm(last, params["final_norm"], cfg.norm_eps)

    glue(final_norm, reads=("x", "real_len"), writes=("hf",))
    _emit_unembed(cfg, params, stages, m_rows=1)

    def finish(env):
        """Epilogue: write the request's KV rows into its reserved slot.

        Mirrors the engine's analytic admission write: the slot row holds
        the S real positions (k rope'd, v raw), zero-padded to cache_len,
        and pos[slot] = S. A single-token request (slot None) leaves the
        cache untouched — it retires at completion without decoding."""
        slot = env["slot"]
        if slot is None:
            return
        S = env["real_len"]
        cache = env["cache"]
        layers = cache["layers"]
        kc, vc = layers["k"], layers["v"]
        cache_len = int(kc.shape[3])
        k_new = jnp.concatenate(env["new_layers"]["k"], axis=0)[:, :, :S]
        v_new = jnp.concatenate(env["new_layers"]["v"], axis=0)[:, :, :S]
        pad = ((0, 0), (0, 0), (0, cache_len - S), (0, 0))
        new_layers = dict(layers)
        new_layers["k"] = kc.at[:, slot].set(
            jnp.pad(k_new, pad).astype(kc.dtype))
        new_layers["v"] = vc.at[:, slot].set(
            jnp.pad(v_new, pad).astype(vc.dtype))
        env["cache"] = {"pos": cache["pos"].at[slot].set(S),
                        "layers": new_layers}

    glue(finish, reads=("cache", "new_layers", "real_len", "slot"),
         writes=("cache",))
    return ProgramTemplate(stages=stages, batch=Sp, model_name=cfg.name,
                           kind="prefill")


def build_dense_decode_program(model, params, tokens: jax.Array, cache,
                               stream_id: int, *, slo_s: float = float("inf"),
                               arrival_t: float = 0.0,
                               deadline_t: float = float("inf"),
                               req_deadlines: Tuple = ()) -> KernelProgram:
    """One-shot compile + bind (the uncached path; kept for callers that
    build a single step). The serving engine instead caches the template
    (``VLIWJit.plan_cache``) and calls ``bind`` per step."""
    template = build_dense_decode_template(model, params,
                                           int(tokens.shape[0]))
    return template.bind(stream_id=stream_id, tokens=tokens, cache=cache,
                         slo_s=slo_s, arrival_t=arrival_t,
                         deadline_t=deadline_t, req_deadlines=req_deadlines)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StreamStat:
    """Streaming aggregate (count/sum/min/max) over one per-superkernel
    observable. Replaces the unbounded per-dispatch lists ``JitStats``
    used to keep — memory grew linearly over long serving sessions —
    while preserving ``mean_group`` and ``merge`` semantics (``+`` folds
    two aggregates)."""

    count: int = 0
    total: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def add(self, x: float) -> None:
        self.count += 1
        self.total += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)

    @classmethod
    def of(cls, xs) -> "StreamStat":
        s = cls()
        for x in xs:
            s.add(x)
        return s

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def __add__(self, other: "StreamStat") -> "StreamStat":
        if not self.count:
            return dataclasses.replace(other)
        if not other.count:
            return dataclasses.replace(self)
        return StreamStat(self.count + other.count, self.total + other.total,
                          min(self.min, other.min), max(self.max, other.max))


@dataclasses.dataclass
class JitStats:
    superkernels: int = 0
    ops_executed: int = 0
    groups: StreamStat = dataclasses.field(default_factory=StreamStat)
    padding_waste: StreamStat = dataclasses.field(default_factory=StreamStat)
    modeled_time_s: float = 0.0
    modeled_serial_time_s: float = 0.0
    shared_dispatches: int = 0
    # event-loop counters
    waits: int = 0                 # stagger (WAIT) decisions taken
    # missed stragglers demoted from EDF anchoring. When request ids are
    # plumbed through the program (serving path), this counts exactly once
    # per missed *request* across all of its steps — even a straggler
    # hidden behind a healthy batchmate's anchor deadline; for raw op
    # streams without ids it falls back to once per (stream, deadline)
    evictions: int = 0
    mid_flight_admissions: int = 0  # programs joining live ops post-start
    # dispatched superkernel groups that packed a prefill op together with
    # at least one other stream's op — the §5.2 spatial-sharing win applied
    # to prompt GEMMs (serving acceptance: must be > 0 on long-prompt
    # multi-tenant traces)
    prefill_coalesced: int = 0
    # non-dense (MoE / SSM) tenant steps compiled+bound as KernelPrograms
    # instead of taking the monolithic batched fallback — the serving
    # engine counts one per decode program it admits for such a tenant
    nondense_programs: int = 0
    # dispatched superkernel groups that packed an MoE per-expert FFN GEMM
    # (tag "expert_*", clustering.is_expert_op) together with at least one
    # other stream's op — the heterogeneous-tenant spatial-sharing win the
    # MoE coalescing benchmark gates on
    expert_coalesced: int = 0
    # plan-cache deltas accrued during this run (core/plancache.py):
    # program templates (ServingEngine._build_program / VLIWJit.plan_cache)
    # and superkernel block plans (Coalescer memo). PlanCacheStats supports
    # ``+`` so merge() folds these like every other counter.
    plan_cache: PlanCacheStats = dataclasses.field(
        default_factory=PlanCacheStats)
    block_plans: PlanCacheStats = dataclasses.field(
        default_factory=PlanCacheStats)
    # live-tuner cache deltas (core/autotuner.LiveTuner / VLIWJit.
    # tune_cache): one access per planned dispatch when live tuning is on
    # (zeros otherwise), a miss only on a never-seen group signature — the
    # compiled-autotune bench gates hit rate ≥ (steps-1)/steps on these.
    tune_cache: PlanCacheStats = dataclasses.field(
        default_factory=PlanCacheStats)
    # jitted dispatch fast-path deltas (core/dispatch.py): packed-weight
    # cache hits/misses/invalidations, retraces of the jitted
    # pack+kernel+unpack, and weight bytes NOT re-staged thanks to the
    # cache. DispatchStats supports ``+`` so merge() folds it like every
    # other counter.
    dispatch: DispatchStats = dataclasses.field(default_factory=DispatchStats)
    # schedule-certifier counters (repro.analysis.certify, wired by
    # ServingEngine(certify=True)): per-op/per-group legality checks run
    # and violations observed. A gating bench asserts violations == 0
    # while checks > 0 — certification that silently checked nothing
    # would otherwise read as a clean pass.
    hazard_checks: int = 0
    hazard_violations: int = 0
    # multi-device mesh counters: modeled cross-device collective seconds
    # charged (MoE expert dispatch/combine for device-spanning tenants —
    # nonzero iff some tenant's expert span > 1), and dispatched groups
    # that actually coalesced (>1 op) — per-session this is a per-DEVICE
    # count, which the multi-device bench requires to be nonzero on every
    # device (a mesh where one device never coalesces is misplaced).
    collective_time_s: float = 0.0
    coalesced_groups: int = 0

    @property
    def mean_group(self) -> float:
        return self.groups.mean

    @property
    def modeled_speedup(self) -> float:
        return self.modeled_serial_time_s / self.modeled_time_s \
            if self.modeled_time_s else 1.0

    def merge(self, other: "JitStats") -> "JitStats":
        """Fold another run's counters into this one (in place). Every
        field accumulates by ``+`` (ints, floats and lists alike), so new
        counters are merged automatically."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self


@dataclasses.dataclass
class TickEvent:
    """Outcome of one scheduler decision on the session's virtual clock."""
    kind: str                      # "dispatch" | "wait" | "idle"
    t: float                       # virtual time after the event
    dt: float = 0.0                # modeled device seconds consumed
    completed: List[KernelProgram] = dataclasses.field(default_factory=list)


# a timed admission: (virtual arrival time, program or zero-arg factory)
Arrival = Tuple[float, Union[KernelProgram, Callable[[], KernelProgram]]]


class JitSession:
    """A live, admission-open run of the VLIW JIT.

    Unlike the closed-world ``VLIWJit.run`` wrapper, a session keeps its
    scheduler, live-op pool and stats across calls: the serving engine admits
    new tenant programs *between superkernel dispatches* and advances the
    shared virtual clock one scheduler decision (``tick``) at a time.
    """

    def __init__(self, jit: "VLIWJit", record_trace: bool = False, *,
                 device: int = 0, cost: Optional[CostModel] = None,
                 trace: Optional[ScheduleTrace] = None):
        self.jit = jit
        self.stats = JitStats()
        # mesh placement: one session drives ONE device's virtual timeline.
        # The scheduler and coalescer are per-device views over the shared
        # JIT state — the coalescer plans with this device's cost model and
        # keys the SHARED block-plan memo with the device id, and the
        # scheduler owns this device's ready pool / EDF anchor set. The
        # default (device 0, jit.cost) is exactly the single-device setup.
        self.device = device
        self.cost = cost if cost is not None else jit.cost
        if device == 0 and cost is None:
            coalescer = jit.coalescer
        else:
            # non-default device: a per-device tuner over THIS device's
            # cost model, sharing the JIT-owned tune cache (device id in
            # every key) — mirrors the per-device coalescer/memo pattern
            tuner = None if jit.tuner is None else \
                LiveTuner(self.cost, jit.tune_cache,
                          objective=jit.tune_objective, device_id=device)
            coalescer = Coalescer(self.cost, max_group=jit.max_group,
                                  memo=jit.block_plans, device_id=device,
                                  tuner=tuner)
        self.sched = OoOScheduler(self.cost, coalescer, jit.sched_cfg,
                                  device=device)
        # expert-parallel span per stream (tenant): streams whose MoE
        # expert weights span >1 devices pay the all-to-all collective
        # charge on every expert GEMM (set by the engine from the
        # placement policy; default 1 = local, no charge).
        self.stream_span: Dict[int, int] = {}
        # dispatch trace for the schedule certifier (repro.analysis):
        # admissions, waits and per-op dispatch records, appended BEFORE
        # each superkernel executes so a crash mid-dispatch still leaves
        # the offending group on the trace. None (default) records
        # nothing — zero steady-state overhead unless certification is on.
        # An explicit ``trace`` shares one audit log across the per-device
        # sessions of a mesh run (the certifier sees the whole fleet).
        self.trace: Optional[ScheduleTrace] = trace if trace is not None \
            else (ScheduleTrace() if record_trace else None)
        # pending GEMM per program: op_id -> (program, stage)
        self.live: Dict[int, Tuple[KernelProgram, GemmStage]] = {}
        self._done: List[KernelProgram] = []
        self._started = False          # True once the first tick has run
        # plan caches and the dispatch executor outlive sessions (that is
        # the point); snapshot their counters so this session's stats
        # report only its own delta
        self._plan_base = jit.plan_cache.stats.copy()
        self._block_base = jit.block_plans.stats.copy()
        self._tune_base = jit.tune_cache.stats.copy()
        self._dispatch_base = jit.executor.stats.copy()

    def _sync_cache_stats(self) -> None:
        self.stats.plan_cache = self.jit.plan_cache.stats - self._plan_base
        self.stats.block_plans = self.jit.block_plans.stats - self._block_base
        self.stats.tune_cache = self.jit.tune_cache.stats - self._tune_base
        self.stats.dispatch = self.jit.executor.stats - self._dispatch_base

    @property
    def pending(self) -> int:
        return len(self.live)

    def set_next_arrival(self, t: float) -> None:
        """Tell the scheduler when the next admission is coming, enabling
        the stagger/WAIT branch on the real path."""
        self.sched.next_arrival_t = t

    def set_stream_span(self, stream_id: int, span: int) -> None:
        """Declare a stream's expert-parallel device span (placement
        policy's ``TenantPlacement.expert_span``). Spans > 1 charge the
        MoE expert dispatch/combine all-to-all on every expert GEMM the
        stream declares from now on."""
        self.stream_span[stream_id] = span

    def admit(self, prog: KernelProgram) -> None:
        """Add a program to the live pool (legal at any point in time)."""
        # mid-flight = joining other streams' live ops after execution has
        # begun; the initial batch of admissions before the first tick is
        # just the starting pool
        if self.live and self._started:
            self.stats.mid_flight_admissions += 1
        prog.device = self.device     # placement binds at admission
        if self.trace is not None:
            self.trace.prog_admits.append(ProgramAdmit(
                prog_uid=prog.uid, stream=prog.stream_id, kind=prog.kind,
                req_ids=tuple(r for r, _ in prog.req_deadlines),
                kv_writes=tuple(prog.kv_writes), device=self.device))
        st = prog.advance_glue()
        if st is None:            # pure-glue program: completes immediately
            self._done.append(prog)
            return
        self._push_op(prog, st)

    def _expert_collective_s(self, stream_id: int, m: int, k: int,
                             layers: int = 1, dtype_bytes: int = 2) -> float:
        """All-to-all charge for one expert-FFN trio of a device-spanning
        MoE stream: dispatch scatters the [m, k] expert activations to the
        shards, combine gathers the outputs back — 2·m·k bytes round trip
        per scanned layer. Charged ONCE per trio (on the gate GEMM) so a
        gate/up/down triple is not triple-billed. Local streams
        (span <= 1) pay nothing."""
        span = self.stream_span.get(stream_id, 1)
        if span <= 1:
            return 0.0
        return self.cost.all_to_all_time(
            2.0 * layers * m * k * dtype_bytes, span)

    def _push_op(self, prog: KernelProgram, st: Stage) -> None:
        if isinstance(st, StackedGemmStage):
            self._push_stacked_op(prog, st)
            return
        a = st.input_fn(prog.env)
        w = st.weight_fn()
        # aspect boundary derived from the JIT's m-tile (kernelspec owns
        # the classification) — a problem within one bm tile is a gemv
        op = make_op(prog.stream_id, op_aspect(int(a.shape[0]), self.jit.bm),
                     GemmShape(m=int(a.shape[0]), n=int(w.shape[1]),
                               k=int(w.shape[0])),
                     arrival_t=prog.arrival_t,
                     deadline_t=prog.effective_deadline,
                     seq_index=prog.pc, tag=st.tag,
                     model_id=st.weight_key[0] if st.weight_key else "",
                     op_kind=prog.kind)
        # carry operand bindings on the op (declarative dispatch payload)
        op.payload = (a, w, st.weight_key)
        op.prog_uid = prog.uid
        op.device = self.device
        if st.tag == "expert_gate":
            op.collective_s = self._expert_collective_s(
                prog.stream_id, op.shape.m, op.shape.k)
        # per-request identity: the scheduler accounts SLO demotions per
        # request id, not per (stream, deadline) of the batch anchor
        op.req_deadlines = prog.req_deadlines
        if math.isfinite(op.deadline_t):
            # EDF anchor = deadline minus the program's remaining critical
            # path (plus any collective charge), so upstream stages inherit
            # the urgency of the whole step
            op.latest_start_t = op.deadline_t \
                - prog.remaining_gemm_time(self.cost, prog.pc) \
                - op.collective_s
        self.live[op.op_id] = (prog, st)
        self.sched.push([op])

    def _push_stacked_op(self, prog: KernelProgram,
                         st: StackedGemmStage) -> None:
        """Declare one layer-stacked body stage as a single KernelOp.

        ``op.shape`` carries the DOMINANT operand (largest total weight
        volume) for EDF/aspect bookkeeping; the full per-operand signature
        rides on ``op.stack`` and drives coalescing (clustering.
        coalesce_key) and the cost charge (L sequential tile-waves per
        operand)."""
        dom = max((od.shape for od in st.operands),
                  key=lambda s: s.layers * s.n * s.k)
        op = make_op(prog.stream_id, op_aspect(dom.m, self.jit.bm), dom,
                     arrival_t=prog.arrival_t,
                     deadline_t=prog.effective_deadline,
                     seq_index=prog.pc, tag=st.tag,
                     model_id=st.weight_key[0],
                     op_kind=prog.kind)
        op.stack = tuple((od.tag, od.shape) for od in st.operands)
        # no eager activation binding — the stacked operands are
        # materialized at dispatch time (_run_stacked); the key slot keeps
        # shared-operand detection uniform with plain ops. The weight slot
        # carries the operand GUARD arrays (the original stacked params,
        # stable across ticks) so op_weight_identity resolves a stacked
        # op's operand identity for the certifier's shared-operand check.
        op.payload = (None,
                      tuple(a for od in st.operands for a in od.guard),
                      st.weight_key)
        op.prog_uid = prog.uid
        op.device = self.device
        # expert-parallel collective: charge the first expert_gate operand
        # of the scanned body (one dispatch+combine per layer of the trio)
        for od in st.operands:
            if od.tag == "expert_gate":
                op.collective_s = self._expert_collective_s(
                    prog.stream_id, od.shape.m, od.shape.k,
                    layers=od.shape.layers,
                    dtype_bytes=od.shape.dtype_bytes)
                break
        op.req_deadlines = prog.req_deadlines
        if math.isfinite(op.deadline_t):
            op.latest_start_t = op.deadline_t \
                - prog.remaining_gemm_time(self.cost, prog.pc) \
                - op.collective_s
        self.live[op.op_id] = (prog, st)
        self.sched.push([op])

    def _op_record(self, op: KernelOp) -> OpRecord:
        """Snapshot one live op for the dispatch trace. Env writes come
        from the stage's declared ``writes`` set — an undeclared stage
        conservatively aliases everything (``("*",)``), qualified by the
        program env's identity so two tenants' private envs never read as
        conflicting resources."""
        prog, st = self.live[op.op_id]
        writes = getattr(st, "writes", None)
        return OpRecord(
            op_id=op.op_id, stream=op.stream_id, prog_uid=op.prog_uid,
            tag=op.tag, seq=op.seq_index, op_kind=op.op_kind,
            deadline_t=op.deadline_t, latest_start_t=op.latest_start_t,
            weight_key=op_weight_key(op), weight_id=op_weight_identity(op),
            kv_writes=tuple(prog.kv_writes),
            env_writes=tuple(writes) if writes is not None else ("*",),
            env_id=id(prog.env), device=op.device)

    def _run_stacked(self, ops, completed,
                     block: Optional[BlockConfig] = None) -> None:
        """Dispatch a coalesced group of layer-stacked body ops: pack each
        op's stacked weight operands through the executor's persistent
        cache, then run the scanned bodies back-to-back. ``block``
        overrides the executor's default tile for the scanned GEMMs (the
        live-tuned config of the plan) — each distinct config compiles its
        own scan body once, keyed beside the executor defaults."""
        ex = self.jit.executor
        for op in ops:
            prog, st = self.live.pop(op.op_id)
            padded = {}
            if not ex.enabled:
                # eager ablation (executor.enabled=False): pad each stacked
                # operand fresh — same envelope, same bits — but through
                # neither the persistent cache nor DispatchStats
                for od in st.operands:
                    w = od.weight_fn()
                    K = envelope_bucket(int(od.shape.k))
                    N = envelope_bucket(int(od.shape.n))
                    pad = [(0, 0)] * (w.ndim - 2) + \
                        [(0, K - int(w.shape[-2])), (0, N - int(w.shape[-1]))]
                    padded[od.tag] = jnp.pad(w, pad)
            else:
                h0, m0 = ex.stats.weight_hits, ex.stats.weight_misses
                for od in st.operands:
                    # params-free group identity: a hot-swap (new params id
                    # in the weight key) changes the key within the same
                    # group, so the cache drops the superseded entry
                    group = (op.stream_id, od.weight_key[0]) \
                        + od.weight_key[2:]
                    padded[od.tag] = ex.stacked_operand(
                        od.weight_key, od.shape.k, od.shape.n,
                        od.shape.layers, od.weight_fn, od.guard,
                        group=group, device=op.device)
                # collapse the per-operand cache accesses into ONE hit/miss
                # event per dispatch (miss iff any operand had to repack)
                # so the DispatchStats invariant hits + misses == dispatches
                # holds across plain and stacked dispatch alike
                missed = ex.stats.weight_misses - m0
                ex.stats.weight_hits, ex.stats.weight_misses = h0, m0
                if missed:
                    ex.stats.weight_misses += 1
                else:
                    ex.stats.weight_hits += 1
                ex.stats.dispatches += 1
            st.run(prog.env, padded, ex, block)
            prog.pc += 1
            nxt = prog.advance_glue()
            if nxt is None:
                completed.append(prog)
            else:
                self._push_op(prog, nxt)

    def tick(self, now: float) -> TickEvent:
        """Execute one scheduler decision at virtual time ``now``."""
        self._sync_cache_stats()
        completed, self._done = self._done, []
        if not self.live:
            return TickEvent("idle", now, completed=completed)
        self._started = True
        decision = self.sched.decide(now)
        self.stats.evictions = self.sched.evictions
        self._sync_cache_stats()
        if decision.kind == "wait":
            self.stats.waits += 1
            if self.trace is not None:
                self.trace.waits.append(decision.wait_until)
            return TickEvent("wait", decision.wait_until, completed=completed)
        assert decision.kind == "dispatch" and decision.plan
        plan = decision.plan
        # operand identity lives with the clustering layer: a group whose
        # ops all carry ONE weight key loads the weights once
        shared = shared_weight_key(plan.ops) is not None
        stacked = plan.ops[0].stack is not None
        if self.trace is not None:
            # record BEFORE execution: a dispatch that crashes (e.g. the
            # executor's shared-operand identity guard) still leaves the
            # offending group on the trace for the certifier's post-mortem
            self.trace.dispatches.append(DispatchRecord(
                t=now, shared_operand=shared, device=self.device,
                ops=tuple(self._op_record(op) for op in plan.ops)))
        # cross-device collective charge of the group (expert-parallel MoE
        # dispatch/combine): one all-to-all covers the group — it is a
        # per-layer exchange, not per-member — so charge the max, exactly
        # as Coalescer.plan does for est_time_s
        coll = max((op.collective_s for op in plan.ops), default=0.0)
        # live tuning: the plan's block IS the tuned config for this
        # group's signature — flow it into the executor so the dispatched
        # kernels actually run the tile the cost model chose. Off (the
        # default), the executor keeps its fixed defaults and nothing about
        # the pre-existing trace-cache population changes.
        tuned_block = plan.block if self.jit.live_tune else None
        if stacked:
            # coalesce_key keeps stacked and plain ops in disjoint buckets
            assert all(op.stack is not None for op in plan.ops)
            serial_shapes = [s for op in plan.ops for _, s in op.stack]
            outs = None
            t = plan.est_time_s          # already includes the collective
        else:
            # the jitted dispatch fast path (core/dispatch.py): persistent
            # packed weights + bucketed envelopes + compiled
            # pack/kernel/unpack
            outs = self.jit.executor.execute(plan.ops,
                                             shared_operand=shared,
                                             device=self.device,
                                             block=tuned_block)
            serial_shapes = [o.shape for o in plan.ops]
            t = self.cost.coalesced_time(serial_shapes, plan.block,
                                         shared_operand=shared) + coll
        stats = self.stats
        stats.superkernels += 1
        stats.ops_executed += len(plan.ops)
        stats.groups.add(len(plan.ops))
        stats.padding_waste.add(plan.padding_waste)
        stats.shared_dispatches += int(shared)
        stats.collective_time_s += coll
        stats.coalesced_groups += int(len(plan.ops) > 1)
        if len({op.stream_id for op in plan.ops}) > 1:
            if any(op.op_kind == "prefill" for op in plan.ops):
                stats.prefill_coalesced += 1
            if any(is_expert_op(op) for op in plan.ops):
                stats.expert_coalesced += 1
        stats.modeled_time_s += t
        stats.modeled_serial_time_s += self.cost.time_multiplexed(
            serial_shapes, plan.block) + coll
        if stacked:
            self._run_stacked(plan.ops, completed, block=tuned_block)
        else:
            for op, out in zip(plan.ops, outs):
                prog, st = self.live.pop(op.op_id)
                st.output_fn(prog.env, out)
                prog.pc += 1
                nxt = prog.advance_glue()
                if nxt is None:
                    completed.append(prog)
                else:
                    self._push_op(prog, nxt)
        # re-sync after the dispatch so a session that ends on this tick
        # still reports the executor/plan-cache work it just did
        self._sync_cache_stats()
        return TickEvent("dispatch", now + t, dt=t, completed=completed)


class VLIWJit:
    """Run tenant KernelPrograms to completion with OoO coalescing."""

    def __init__(self, cost: Optional[CostModel] = None,
                 sched_cfg: SchedulerConfig = SchedulerConfig(),
                 max_group: int = 16, bm: int = 8,
                 plan_capacity: int = 128,
                 weight_capacity: Optional[int] = None,
                 weight_budget_bytes: Optional[int] = 1 << 30,
                 live_tune: bool = False,
                 tune_objective: str = "collaborative"):
        self.cost = cost or CostModel(TPUV5E)
        # persistent plan caches (core/plancache.py): program templates for
        # the serving hot path and superkernel block plans per coalesced
        # group signature. They live on the JIT — across sessions — so
        # steady-state ticks only rebind per-step state.
        # plan_capacity=0 disables both (the rebuild-per-step baseline).
        self.plan_cache = PlanCache(plan_capacity)
        self.block_plans = PlanCache(plan_capacity * 4)
        self.max_group = max_group
        # live collaborative autotuning (core/autotuner.LiveTuner): when
        # on, every coalescer consults the tuner per plan and the tuned
        # (bm, bn, bk) flows into the dispatched superkernels. TuneResults
        # live in their own device-keyed PlanCache BESIDE the block plans
        # — same lifetime (the JIT's), separately accounted
        # (JitStats.tune_cache) because the hit rate is a gated serving
        # acceptance criterion. The cache exists even with live_tune=False
        # so session stat plumbing is unconditional (its stats stay zero).
        self.tune_cache = PlanCache(plan_capacity * 4)
        self.live_tune = live_tune
        self.tune_objective = tune_objective
        self.tuner = LiveTuner(self.cost, self.tune_cache,
                               objective=tune_objective) if live_tune \
            else None
        self.coalescer = Coalescer(self.cost, max_group=max_group,
                                   memo=self.block_plans, tuner=self.tuner)
        self.sched_cfg = sched_cfg
        self.bm = bm
        # the jitted dispatch fast path (core/dispatch.py): packed weight
        # operands cached across sessions, bucketed envelopes, compiled
        # pack+kernel+unpack. Entries are full padded weight copies, so
        # the entry-count bound (weight_capacity, default tracks
        # plan_capacity; 0 = repack per dispatch, still jitted) does NOT
        # bound memory at real model sizes — weight_budget_bytes does (LRU
        # evicts past the byte budget, default 1 GiB; None = unbounded).
        wcap = 2 * plan_capacity if weight_capacity is None else \
            weight_capacity
        self.weight_cache = PlanCache(wcap,
                                      byte_capacity=weight_budget_bytes)
        self.executor = SuperkernelExecutor(self.weight_cache, bm=bm)

    def session(self, record_trace: bool = False, *, device: int = 0,
                cost: Optional[CostModel] = None,
                trace: Optional[ScheduleTrace] = None) -> JitSession:
        """Open an admission-open event-loop session (engine entry point).

        ``record_trace=True`` makes the session keep a ``ScheduleTrace``
        (admissions, waits, per-op dispatch records) for the schedule
        certifier — the engine's ``certify=True`` path. Multi-device
        serving opens one session PER mesh device (``device``/``cost``
        from the ``DeviceSet``) sharing this JIT's caches — keyed with the
        device id — and optionally one shared ``trace``."""
        return JitSession(self, record_trace=record_trace, device=device,
                          cost=cost, trace=trace)

    def run(self, programs: Sequence[KernelProgram],
            arrivals: Optional[Sequence[Arrival]] = None,
            start_t: float = 0.0) -> JitStats:
        """Drive a session to completion on a virtual clock.

        ``programs`` are admitted at ``start_t``; each ``(t, program)`` in
        ``arrivals`` is admitted mid-flight once the clock reaches ``t``
        (a zero-arg factory is called at admission time, letting callers
        defer program construction until its inputs exist).
        """
        session = self.session()
        for prog in programs:
            session.admit(prog)
        queue = sorted(arrivals or (), key=lambda e: e[0])
        qi = 0
        now = start_t
        while True:
            while qi < len(queue) and queue[qi][0] <= now:
                entry = queue[qi][1]
                session.admit(entry() if callable(entry) else entry)
                qi += 1
            session.set_next_arrival(queue[qi][0] if qi < len(queue)
                                     else math.inf)
            ev = session.tick(now)
            if ev.kind == "idle":
                if qi < len(queue):
                    now = queue[qi][0]
                    continue
                break
            now = max(now, ev.t)
        return session.stats
