"""The OoO VLIW JIT runtime — real execution path.

This is the paper's Figure 1 made concrete: multiple tenant streams, each an
*instruction stream* of declared kernel ops, multiplexed onto one device by
(a) clustering + coalescing compatible GEMMs into Pallas superkernels and
(b) OoO, SLO-aware interleaving of the streams.

Execution model (TPU adaptation, DESIGN.md §2): a tenant's decode step is
compiled into a ``KernelProgram`` — an alternating sequence of GEMM stages
(declared to the JIT, coalescible across tenants) and glue stages (norms,
rope, cache updates, softmax — executed eagerly per tenant). The engine
advances all tenants concurrently: at each tick it collects every tenant's
pending GEMM, asks the OoO scheduler for the best coalesced group, executes
it via ``kernels.ops.execute_superkernel``, and resumes the affected
tenants. Tenants at *different* program positions still coalesce whenever
their problem shapes fall in the same cluster — that is the OoO part.

Correctness: running a program must produce bit-comparable results to the
monolithic ``Model.decode_step`` (tests/test_jit_engine.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coalescer import Coalescer
from repro.core.costmodel import CostModel, GemmShape, TPUV5E
from repro.core.kernelspec import KernelOp, make_op
from repro.core.scheduler import OoOScheduler, SchedulerConfig
from repro.kernels.ops import execute_superkernel
from repro.models.layers import rmsnorm, apply_rope


# ---------------------------------------------------------------------------
# kernel programs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GemmStage:
    tag: str                       # cluster tag, e.g. "L3.ffn_gate"
    weight_key: Tuple              # identity key for operand sharing
    weight_fn: Callable[[], jax.Array]
    # consumes env, returns the activation matrix [m, k]
    input_fn: Callable[[Dict[str, Any]], jax.Array]
    # receives (env, gemm_output)
    output_fn: Callable[[Dict[str, Any], jax.Array], None]


@dataclasses.dataclass
class GlueStage:
    fn: Callable[[Dict[str, Any]], None]


Stage = Any  # GemmStage | GlueStage


@dataclasses.dataclass
class KernelProgram:
    """One tenant step: stages + a private environment."""
    stream_id: int
    stages: List[Stage]
    env: Dict[str, Any]
    pc: int = 0
    slo_s: float = float("inf")
    arrival_t: float = 0.0

    def done(self) -> bool:
        return self.pc >= len(self.stages)

    def advance_glue(self) -> Optional[GemmStage]:
        """Run glue stages until the next GEMM (or completion)."""
        while self.pc < len(self.stages):
            st = self.stages[self.pc]
            if isinstance(st, GemmStage):
                return st
            st.fn(self.env)
            self.pc += 1
        return None


# ---------------------------------------------------------------------------
# program builder for dense GQA decode (the real-execution demo family)
# ---------------------------------------------------------------------------

def build_dense_decode_program(model, params, tokens: jax.Array, cache,
                               stream_id: int, *, slo_s: float = float("inf"),
                               arrival_t: float = 0.0) -> KernelProgram:
    """Compile one decode step of a dense GQA model into a KernelProgram.

    Equivalent to ``Model.decode_step`` but with every projection GEMM
    declared to the JIT. Supported: arch_type 'dense' (and the text path of
    'vlm'). tokens: [B, 1].
    """
    cfg: ModelConfig = model.cfg
    assert cfg.arch_type in ("dense", "vlm"), cfg.arch_type
    hd = cfg.resolved_head_dim
    B = tokens.shape[0]
    blocks = params["blocks"]
    stages: List[Stage] = []
    env: Dict[str, Any] = {"cache": cache, "new_layers": {"k": [], "v": []}}

    def glue(fn):
        stages.append(GlueStage(fn))

    def gemm(tag, wkey, wfn, infn, outfn):
        stages.append(GemmStage(tag, wkey, wfn, infn, outfn))

    def embed(env):
        x = params["embed"][tokens]
        env["x"] = (x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype))[:, 0]
        env["pos"] = env["cache"]["pos"]

    glue(embed)

    for l in range(cfg.num_layers):
        lp = jax.tree_util.tree_map(lambda a, l=l: a[l], blocks)
        is_global = cfg.layer_is_global(l)

        def pre_attn(env, lp=lp):
            env["h"] = rmsnorm(env["x"], lp["ln1"], cfg.norm_eps)

        glue(pre_attn)
        for name, n_heads in (("wq", cfg.num_heads), ("wk", cfg.num_kv_heads),
                              ("wv", cfg.num_kv_heads)):
            gemm(f"attn_{name}", (cfg.name, l, name),
                 lambda lp=lp, name=name: lp["attn"][name],
                 lambda env: env["h"],
                 lambda env, out, name=name: env.__setitem__(name, out))

        def attend(env, lp=lp, l=l, is_global=is_global):
            cache = env["cache"]
            pos = jnp.broadcast_to(jnp.asarray(cache["pos"]), (B,))
            q = env["wq"].reshape(B, 1, cfg.num_heads, hd)
            k = env["wk"].reshape(B, 1, cfg.num_kv_heads, hd)
            v = env["wv"].reshape(B, 1, cfg.num_kv_heads, hd)
            posb = pos[:, None]
            q = apply_rope(q, posb, cfg.rope_theta)
            k = apply_rope(k, posb, cfg.rope_theta)
            upd = jax.vmap(lambda c, kn, p: jax.lax.dynamic_update_slice(
                c, kn, (0, p, 0)))
            kc = upd(cache["layers"]["k"][l],
                     k.transpose(0, 2, 1, 3).astype(
                         cache["layers"]["k"].dtype), pos)
            vc = upd(cache["layers"]["v"][l],
                     v.transpose(0, 2, 1, 3).astype(
                         cache["layers"]["v"].dtype), pos)
            env["new_layers"]["k"].append(kc)
            env["new_layers"]["v"].append(vc)
            S = kc.shape[2]
            G = cfg.num_heads // cfg.num_kv_heads
            qg = q.reshape(B, 1, cfg.num_kv_heads, G, hd)
            scores = jnp.einsum("bshgd,bhtd->bhgst", qg, kc,
                                preferred_element_type=jnp.float32)
            scores = scores / jnp.sqrt(jnp.float32(hd))
            idx = jnp.arange(S)
            ok = idx[None, :] <= pos[:, None]
            if cfg.window_size > 0 and not is_global:
                ok = ok & (idx[None, :] > (pos[:, None] - cfg.window_size))
            scores = jnp.where(ok[:, None, None, None, :], scores, -2.0e38)
            p = jax.nn.softmax(scores, axis=-1)
            o = jnp.einsum("bhgst,bhtd->bshgd", p, vc.astype(jnp.float32))
            env["attn_out"] = o.reshape(B, cfg.num_heads * hd).astype(
                env["h"].dtype)

        glue(attend)
        gemm("attn_wo", (cfg.name, l, "wo"),
             lambda lp=lp: lp["attn"]["wo"],
             lambda env: env["attn_out"],
             lambda env, out: env.__setitem__("attn_proj", out))

        def post_attn(env, lp=lp):
            env["x"] = env["x"] + env["attn_proj"]
            env["h2"] = rmsnorm(env["x"], lp["ln2"], cfg.norm_eps)

        glue(post_attn)
        gemm("ffn_gate", (cfg.name, l, "w_gate"),
             lambda lp=lp: lp["mlp"]["w_gate"],
             lambda env: env["h2"],
             lambda env, out: env.__setitem__("gate", out))
        gemm("ffn_up", (cfg.name, l, "w_up"),
             lambda lp=lp: lp["mlp"]["w_up"],
             lambda env: env["h2"],
             lambda env, out: env.__setitem__("up", out))

        def act(env):
            env["act"] = jax.nn.silu(env["gate"]) * env["up"]

        glue(act)
        gemm("ffn_down", (cfg.name, l, "w_down"),
             lambda lp=lp: lp["mlp"]["w_down"],
             lambda env: env["act"],
             lambda env, out: env.__setitem__("down", out))

        def post_ffn(env):
            env["x"] = env["x"] + env["down"]

        glue(post_ffn)

    def final_norm(env):
        env["hf"] = rmsnorm(env["x"], params["final_norm"], cfg.norm_eps)

    glue(final_norm)
    if cfg.tie_embeddings:
        gemm("unembed", (cfg.name, "unembed"),
             lambda: params["embed"].T,
             lambda env: env["hf"],
             lambda env, out: env.__setitem__("logits", out))
    else:
        gemm("unembed", (cfg.name, "unembed"),
             lambda: params["unembed"],
             lambda env: env["hf"],
             lambda env, out: env.__setitem__("logits", out))

    def finish(env):
        cache = env["cache"]
        env["cache"] = {
            "pos": cache["pos"] + 1,
            "layers": {
                "k": jnp.stack(env["new_layers"]["k"]),
                "v": jnp.stack(env["new_layers"]["v"]),
            },
        }

    glue(finish)
    return KernelProgram(stream_id=stream_id, stages=stages, env=env,
                         slo_s=slo_s, arrival_t=arrival_t)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JitStats:
    superkernels: int = 0
    ops_executed: int = 0
    groups: List[int] = dataclasses.field(default_factory=list)
    padding_waste: List[float] = dataclasses.field(default_factory=list)
    modeled_time_s: float = 0.0
    modeled_serial_time_s: float = 0.0
    shared_dispatches: int = 0

    @property
    def mean_group(self) -> float:
        return sum(self.groups) / len(self.groups) if self.groups else 0.0

    @property
    def modeled_speedup(self) -> float:
        return self.modeled_serial_time_s / self.modeled_time_s \
            if self.modeled_time_s else 1.0


class VLIWJit:
    """Run a set of tenant KernelPrograms to completion with coalescing."""

    def __init__(self, cost: Optional[CostModel] = None,
                 sched_cfg: SchedulerConfig = SchedulerConfig(),
                 max_group: int = 16, bm: int = 8):
        self.cost = cost or CostModel(TPUV5E)
        self.coalescer = Coalescer(self.cost, max_group=max_group)
        self.sched_cfg = sched_cfg
        self.bm = bm

    def run(self, programs: Sequence[KernelProgram]) -> JitStats:
        stats = JitStats()
        sched = OoOScheduler(self.cost, self.coalescer, self.sched_cfg)
        # pending GEMM per stream: op_id -> (program, stage)
        live: Dict[int, Tuple[KernelProgram, GemmStage]] = {}

        def admit(prog: KernelProgram) -> None:
            st = prog.advance_glue()
            if st is None:
                return
            a = st.input_fn(prog.env)
            w = st.weight_fn()
            op = make_op(prog.stream_id, "gemm" if a.shape[0] > 8 else "gemv",
                         GemmShape(m=int(a.shape[0]), n=int(w.shape[1]),
                                   k=int(w.shape[0])),
                         arrival_t=prog.arrival_t,
                         deadline_t=prog.arrival_t + prog.slo_s,
                         seq_index=prog.pc, tag=st.tag,
                         model_id=st.weight_key[0] if st.weight_key else "")
            # carry operand bindings on the op (declarative dispatch payload)
            op.payload = (a, w, st.weight_key)  # type: ignore[attr-defined]
            live[op.op_id] = (prog, st)
            sched.push([op])

        for prog in programs:
            admit(prog)

        now = 0.0
        while live:
            decision = sched.decide(now)
            if decision.kind == "wait":
                now = decision.wait_until
                continue
            assert decision.kind == "dispatch" and decision.plan
            plan = decision.plan
            problems = [op.payload[:2] for op in plan.ops]  # type: ignore[attr-defined]
            wkeys = {op.payload[2] for op in plan.ops}      # type: ignore[attr-defined]
            shared = len(wkeys) == 1 and len(plan.ops) > 1
            outs = execute_superkernel(problems, bm=self.bm,
                                       shared_operand=shared)
            stats.superkernels += 1
            stats.ops_executed += len(plan.ops)
            stats.groups.append(len(plan.ops))
            stats.padding_waste.append(plan.padding_waste)
            stats.shared_dispatches += int(shared)
            t = self.cost.coalesced_time([o.shape for o in plan.ops],
                                         plan.block, shared_operand=shared)
            stats.modeled_time_s += t
            stats.modeled_serial_time_s += self.cost.time_multiplexed(
                [o.shape for o in plan.ops], plan.block)
            now += t
            for op, out in zip(plan.ops, outs):
                prog, st = live.pop(op.op_id)
                st.output_fn(prog.env, out)
                prog.pc += 1
                admit(prog)
        return stats
