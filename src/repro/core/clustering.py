"""GEMM shape clustering (paper Fig. 7).

The paper's observation: matrix-multiply problems across production DNNs
concentrate into a small number of (n, k) clusters, so cross-stream problems
can be coalesced into superkernels with minimal padding. We cluster in
log-space over (n, k) — the weight dims, which must match exactly or pad —
and keep m (the token/batch dim) free, because the coalesced kernel
concatenates problems along m.

Two levels:
  * ``exact_key``      — problems coalescible with ZERO padding (same n, k);
  * ``cluster_greedy`` — agglomerative log-space clustering with a padding-
    waste bound, reproducing the A/B/C superkernel clusters of Fig. 7.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from repro.core.costmodel import GemmShape
from repro.core.kernelspec import KernelOp


def exact_key(shape: GemmShape) -> Tuple[int, int, int]:
    return (shape.n, shape.k, shape.dtype_bytes)


@dataclasses.dataclass
class Cluster:
    """A set of problems padded to a common (n, k) envelope."""

    members: List[GemmShape]

    @property
    def pad_n(self) -> int:
        return max(s.n for s in self.members)

    @property
    def pad_k(self) -> int:
        return max(s.k for s in self.members)

    @property
    def useful_flops(self) -> float:
        return sum(s.flops for s in self.members)

    @property
    def padded_flops(self) -> float:
        n, k = self.pad_n, self.pad_k
        return sum(2.0 * s.m * n * k * s.layers for s in self.members)

    @property
    def padding_waste(self) -> float:
        """Fraction of superkernel flops burned on padding (0 = perfect)."""
        pf = self.padded_flops
        return 0.0 if pf == 0 else 1.0 - self.useful_flops / pf


def _log_dist(a: GemmShape, b: GemmShape) -> float:
    return math.hypot(math.log2(a.n) - math.log2(b.n),
                      math.log2(a.k) - math.log2(b.k))


def cluster_greedy(shapes: Sequence[GemmShape], max_waste: float = 0.25
                   ) -> List[Cluster]:
    """Greedy agglomerative clustering under a padding-waste bound.

    Problems are sorted by (n, k) volume and greedily absorbed into the
    nearest existing cluster if the merged padding waste stays below
    ``max_waste``; otherwise they seed a new cluster. Deterministic and
    O(S·C) — the populations involved are small (paper §5.3: 'the set of
    operations to coalesce is restricted largely to algebraic tensor ops').
    """
    clusters: List[Cluster] = []
    for s in sorted(shapes, key=lambda s: (s.n * s.k, s.n, s.k), reverse=True):
        best, best_d = None, float("inf")
        for c in clusters:
            trial = Cluster(c.members + [s])
            if trial.padding_waste <= max_waste:
                d = _log_dist(s, c.members[0])
                if d < best_d:
                    best, best_d = c, d
        if best is None:
            clusters.append(Cluster([s]))
        else:
            best.members.append(s)
    return clusters


# ---------------------------------------------------------------------------
# weight-key schema — the operand-identity layer of the coalescing space
# ---------------------------------------------------------------------------
# Coalescing ELIGIBILITY is (n, k, dtype) only — or the full stack signature
# for layer-stacked ops — but two finer identities ride on the ops and
# matter to the dispatch layer:
#   * the weight KEY (op.payload[2], attached by JitSession._push_op): ops
#     sharing one key literally serve the same weight array(s), so the whole
#     group collapses to a single weight load (the shared-operand regime);
#   * the EXPERT tag prefix: MoE tenants emit each expert FFN GEMM as its
#     own stage tagged "expert_*" with the expert index in the weight key,
#     so the same expert's GEMMs coalesce across tenants (and with dense
#     FFN GEMMs sharing their (n, k)) — the scenario-diversity win counted
#     by JitStats.expert_coalesced.
#
# ``weight_key`` below is THE single key constructor (used by core/jit.py
# builders and core/dispatch.py matvec): the schema used to be rebuilt
# ad-hoc at each emission site with the layer index assumed at a fixed
# tuple position, which would have silently broken shared-operand detection
# the moment stacked keys (no per-layer index) appeared. The shapes are:
#
#   per-layer operand   (model, pid, layer:int, name[, expert])
#   stacked operand     (model, pid, "stack", lo, hi, name[, expert])
#   model-level operand (model, pid, name)            e.g. "unembed"
#   raw matvec          ("matvec"|"matvec-shared", id(w))
#
# The "stack" marker cannot collide with the other forms at position 2:
# per-layer keys hold an int there and model-level keys hold an operand
# name, which is never the reserved string "stack".

EXPERT_TAG_PREFIX = "expert_"


def weight_key(model_name: str, params_id: int, name: str, *,
               layer=None, expert=None, stack=None) -> Tuple:
    """Build an operand-identity key (single schema for all emitters).

    ``stack=(lo, hi)`` names one stacked operand covering layers
    [lo, hi) — one key per homogeneous sub-stack, layer index dropped.
    ``layer`` names a per-layer slice (the stacked_layers=False oracle
    path). Neither → a model-level operand (tied unembed etc.).
    ``expert`` appends the MoE expert index in either regime.
    """
    if stack is not None:
        lo, hi = stack
        key: Tuple = (model_name, params_id, "stack", int(lo), int(hi), name)
    elif layer is not None:
        key = (model_name, params_id, int(layer), name)
    else:
        key = (model_name, params_id, name)
    if expert is not None:
        key = key + (int(expert),)
    return key


def matvec_weight_key(w, shared: bool = False) -> Tuple:
    """Identity key for a raw (non-program) matvec weight array."""
    return ("matvec-shared" if shared else "matvec", id(w))


def op_weight_key(op: KernelOp):
    """The op's operand-identity key, or None for raw (payload-free) ops."""
    return op.payload[2] if op.payload is not None else None


def shared_weight_key(ops: Sequence[KernelOp]):
    """The single weight key every op of the group carries — the condition
    for the shared-operand dispatch regime (one weight load serves the
    whole group) — or None (incl. singleton groups and raw op streams)."""
    if len(ops) < 2:
        return None
    key = op_weight_key(ops[0])
    if key is None:
        return None
    return key if all(op_weight_key(op) == key for op in ops[1:]) else None


def op_weight_identity(op: KernelOp):
    """Identity (ids) of the array(s) the op's weight binding resolved to,
    or None when nothing is bound yet.

    This is what the shared-operand LEGALITY check compares: equal weight
    *keys* are supposed to imply the identical weight *array* (one load
    serves the group), and the schedule certifier verifies that
    implication on every shared dispatch instead of trusting it. Plain ops
    carry their weight in ``payload[1]``; stacked ops bind lazily, so
    their identity is the tuple of operand-guard array ids the session
    attaches in ``payload[1]`` (see JitSession._push_stacked_op)."""
    if op.payload is None:
        return None
    w = op.payload[1]
    if w is None:
        return None
    return tuple(id(a) for a in w) if isinstance(w, tuple) else (id(w),)


def is_expert_op(op: KernelOp) -> bool:
    """True for a per-expert MoE FFN GEMM (tag "expert_gate/up/down"),
    or for a stacked layer body that carries expert operands."""
    if op.tag.startswith(EXPERT_TAG_PREFIX):
        return True
    return op.stack is not None and any(
        tag.startswith(EXPERT_TAG_PREFIX) for tag, _ in op.stack)


def coalesce_key(op: KernelOp) -> Tuple:
    """The op's zero-padding coalescing bucket.

    Plain ops bucket on (n, k, dtype) — m stays free (problems concatenate
    along m). A layer-stacked op buckets on its FULL stack signature: the
    ordered (tag, layers, n, k, dtype) tuple of every operand in the
    scanned body, m again free — so two tenants of the same depth-and-dims
    config coalesce their *entire stacks* in one group, while differing
    depths or operand sets (which could not share one scan) never mix.
    The leading "stack" marker keeps stacked buckets disjoint from plain
    (n, k, dtype) triples.

    The op's DEVICE placement leads every key: coalescing is a per-device
    act (one superkernel launches on one device), so ops assigned to
    different devices must never share a bucket — enforced structurally
    here rather than by a scheduler-side filter, and double-checked by the
    schedule certifier's PlacementHazard. Single-device runs put device=0
    everywhere, so the grouping is unchanged.
    """
    if op.stack is not None:
        return ("stack", op.device) + tuple(
            (tag, s.layers, s.n, s.k, s.dtype_bytes) for tag, s in op.stack)
    return (op.device,) + exact_key(op.shape)


def group_ops_exact(ops: Sequence[KernelOp]) -> Dict[Tuple, List[KernelOp]]:
    """Bucket ready ops by zero-padding coalescing key (``coalesce_key``:
    exact n, k, dtype — or the full stack signature for stacked ops).

    The m (token/row) dimension — and with it the gemv/gemm aspect and the
    decode/prefill phase — is deliberately NOT part of the key: coalesced
    superkernels concatenate problems along m, so a tall prompt-prefill GEMM
    packs with decode GEMVs that share its weight dims. Splitting on aspect
    used to keep prefill traffic out of every decode group, serializing
    exactly the large under-filled kernels the paper overlaps.
    """
    groups: Dict[Tuple, List[KernelOp]] = {}
    for op in ops:
        key = coalesce_key(op)
        groups.setdefault(key, []).append(op)
    return groups
