"""Out-of-order, SLO-aware space-time scheduler (paper §5.2).

The scheduler owns the ready queue of declared ops across all streams and
decides, at each device-free instant, between:

  * DISPATCH — issue the best coalesced superkernel now;
  * WAIT     — deliberately delay (stagger) because the cost model predicts a
               better-packed superkernel within the earliest-deadline op's
               slack window (paper: "purposefully delays/staggers ill-fitting
               kernels for better coalescing at a (slightly) later time").

Deadline accounting is per-op: an op's *latest start* is its request deadline
minus the modeled critical-path time of everything still ahead of it in its
stream. EDF over latest-start drives priority. Ops whose request deadline has
already passed are *evicted* from the EDF anchor set (paper §5.2 evicts
degraded stragglers rather than letting them cascade misses onto healthy
requests) — they still execute, but only opportunistically inside whatever
group the healthy anchor forms, or once nothing on-time remains; each
demotion is counted in ``evictions``.

The engine/JIT feeds ``next_arrival_t`` (the next known future admission)
before every ``decide`` call; a WAIT is only ever issued for a strictly
future instant, so the caller's ``now = wait_until`` loop cannot livelock on
a stale or already-elapsed arrival time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.clustering import group_ops_exact
from repro.core.coalescer import Coalescer, SuperkernelPlan
from repro.core.costmodel import CostModel
from repro.core.kernelspec import KernelOp


@dataclasses.dataclass
class Decision:
    kind: str                      # "dispatch" | "wait" | "idle"
    plan: Optional[SuperkernelPlan] = None
    wait_until: float = 0.0


@dataclasses.dataclass
class SchedulerConfig:
    max_group: int = 64
    # minimum modeled benefit (seconds) required to justify waiting
    min_wait_gain_s: float = 2e-6
    # never wait longer than this even with infinite slack
    max_wait_s: float = 500e-6
    # target device fill: stop growing a group once it reaches this many tiles
    target_tiles: int = 0          # 0 -> device.num_units


class OoOScheduler:
    def __init__(self, cost: CostModel, coalescer: Coalescer,
                 cfg: SchedulerConfig = SchedulerConfig(), *,
                 device: int = 0):
        self.cost = cost
        self.coalescer = coalescer
        self.cfg = cfg
        # mesh placement: this scheduler instance owns ONE device's op pool
        # (its own ready queue, EDF anchor set and virtual-clock free
        # instant). Multi-device serving runs N of these side by side —
        # ``push`` asserts every op was placed here, so a placement bug
        # surfaces at admission rather than as a certifier hazard later.
        self.device = device
        self.ready: List[KernelOp] = []
        # per-stream remaining critical path (sum of modeled op times)
        self._stream_remaining: Dict[int, float] = {}
        # next expected arrival (the simulator/engine tells us)
        self.next_arrival_t: float = math.inf
        # SLO-aware eviction bookkeeping: streams demoted out of the EDF
        # anchor set because their deadline passed before they could start.
        # Ops that carry per-request identity (``KernelOp.req_deadlines``,
        # plumbed by the serving engine through the KernelProgram) are
        # accounted under ``("req", req_id)`` — exactly once per missed
        # request across all of its steps, including a straggler batched
        # next to healthy batchmates whose anchor deadline hides it. Raw
        # op streams without ids fall back to (stream, deadline) keys.
        # The set must persist for the scheduler's lifetime: successive
        # step programs of the same missed request re-push ops under the
        # same key, and purging it would double-count them. Growth is one
        # small tuple per missed request per session.
        self.evictions: int = 0
        self._demoted: Set[Tuple] = set()

    def _count_demotion(self, key: Tuple) -> None:
        if key not in self._demoted:
            self._demoted.add(key)
            self.evictions += 1

    def demoted_requests(self) -> Set[int]:
        """Request ids demoted (evicted) from EDF anchoring so far — the
        ``("req", rid)`` entries of the dedup set. The serving engine feeds
        these into the schedule certifier's conservation check: an admitted
        request must retire, appear here, or surface unfinished."""
        return {key[1] for key in self._demoted
                if len(key) == 2 and key[0] == "req"}

    # ------------------------------------------------------------------
    # queue management
    # ------------------------------------------------------------------
    def annotate_stream(self, ops: Sequence[KernelOp]) -> None:
        """Compute per-op latest-start deadlines for one stream's program.

        Cross-device collective charges (``KernelOp.collective_s``) are
        part of the critical path behind the op, so they tighten the
        latest start exactly like GEMM time."""
        suffix = 0.0
        times = [self.cost.gemm_time(op.shape) + op.collective_s
                 for op in ops]
        for op, t in zip(reversed(list(ops)), reversed(times)):
            suffix += t
            op.latest_start_t = op.deadline_t - suffix

    def push(self, ops: Sequence[KernelOp]) -> None:
        for op in ops:
            assert op.device == self.device, (
                f"op {op.op_id} placed on device {op.device} pushed to "
                f"device {self.device}'s pool")
            if math.isinf(op.latest_start_t):
                op.latest_start_t = op.deadline_t - (
                    self.cost.gemm_time(op.shape) + op.collective_s)
        self.ready.extend(ops)

    def pending(self) -> int:
        return len(self.ready)

    # ------------------------------------------------------------------
    # the decision procedure
    # ------------------------------------------------------------------
    def decide(self, now: float) -> Decision:
        if not self.ready:
            return Decision("idle")
        cfg = self.cfg
        target_tiles = cfg.target_tiles or self.cost.device.num_units

        # 0. SLO-aware eviction: ops whose request deadline has already
        #    passed are demoted out of the EDF anchor set so they cannot
        #    cascade misses onto healthy requests (paper §5.2). They still
        #    run — opportunistically inside the anchor's group, or alone once
        #    nothing on-time remains.
        on_time: List[KernelOp] = []
        for op in self.ready:
            # per-request accounting: any batched request whose own final
            # deadline has passed counts once, even when the op itself is
            # still on time because a healthy batchmate anchors its deadline
            for rid, dl in op.req_deadlines:
                if dl <= now:
                    self._count_demotion(("req", rid))
            if op.deadline_t <= now:
                if not op.req_deadlines:
                    self._count_demotion((op.stream_id, op.deadline_t))
                # ops with ids were already counted per request above
            else:
                on_time.append(op)

        # 1. EDF anchor: the earliest latest-start among on-time ops
        anchor = min(on_time or self.ready, key=lambda o: o.latest_start_t)

        # 2. its zero-padding coalescing group among ready ops
        groups = group_ops_exact(self.ready)
        akey = next(k for k, v in groups.items() if anchor in v)
        # order by urgency with missed stragglers last; anchor stays first
        group = sorted(groups[akey],
                       key=lambda o: (o.deadline_t <= now, o.latest_start_t))
        group = group[: cfg.max_group]
        plan = self.coalescer.plan(group)

        # 3. stagger decision: is the group under-filling the device, and
        #    does the anchor have slack to wait for more arrivals?
        tiles = sum(self.cost.tiles(s, plan.block) for s in plan.shapes)
        slack = anchor.latest_start_t - now
        wait_until = min(now + slack, self.next_arrival_t,
                         now + cfg.max_wait_s)
        # wait_until must be strictly in the future: a WAIT that does not
        # advance the caller's virtual clock (stale/elapsed next_arrival_t)
        # would livelock the dispatch loop.
        if (tiles < target_tiles and slack > 0 and wait_until > now
                and self.next_arrival_t < now + min(slack, cfg.max_wait_s)):
            # napkin check: modeled gain of one more same-shape problem
            probe = KernelOp(-1, -1, anchor.kind, anchor.shape)
            gain = self.coalescer.marginal_gain(group, probe)
            if gain > cfg.min_wait_gain_s:
                return Decision("wait", wait_until=wait_until)

        for op in plan.ops:
            self.ready.remove(op)
        return Decision("dispatch", plan=plan)

    # ------------------------------------------------------------------
    def drain(self, now: float = 0.0) -> List[SuperkernelPlan]:
        """Dispatch everything (no waiting) — used by tests and batch mode."""
        plans = []
        self.next_arrival_t = math.inf
        while self.ready:
            d = self.decide(now)
            assert d.kind == "dispatch" and d.plan is not None
            plans.append(d.plan)
            now += d.plan.est_time_s
        return plans
