"""Out-of-order, SLO-aware space-time scheduler (paper §5.2).

The scheduler owns the ready queue of declared ops across all streams and
decides, at each device-free instant, between:

  * DISPATCH — issue the best coalesced superkernel now;
  * WAIT     — deliberately delay (stagger) because the cost model predicts a
               better-packed superkernel within the earliest-deadline op's
               slack window (paper: "purposefully delays/staggers ill-fitting
               kernels for better coalescing at a (slightly) later time").

Deadline accounting is per-op: an op's *latest start* is its request deadline
minus the modeled critical-path time of everything still ahead of it in its
stream. EDF over latest-start drives priority; ops past latest start are
issued immediately (alone if nothing matches), and requests whose deadline is
already unmeetable are counted as misses but still run (paper §5.2 evicts
degraded stragglers rather than cascading them).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.clustering import group_ops_exact
from repro.core.coalescer import Coalescer, SuperkernelPlan
from repro.core.costmodel import CostModel
from repro.core.kernelspec import KernelOp


@dataclasses.dataclass
class Decision:
    kind: str                      # "dispatch" | "wait" | "idle"
    plan: Optional[SuperkernelPlan] = None
    wait_until: float = 0.0


@dataclasses.dataclass
class SchedulerConfig:
    max_group: int = 64
    # minimum modeled benefit (seconds) required to justify waiting
    min_wait_gain_s: float = 2e-6
    # never wait longer than this even with infinite slack
    max_wait_s: float = 500e-6
    # target device fill: stop growing a group once it reaches this many tiles
    target_tiles: int = 0          # 0 -> device.num_units


class OoOScheduler:
    def __init__(self, cost: CostModel, coalescer: Coalescer,
                 cfg: SchedulerConfig = SchedulerConfig()):
        self.cost = cost
        self.coalescer = coalescer
        self.cfg = cfg
        self.ready: List[KernelOp] = []
        # per-stream remaining critical path (sum of modeled op times)
        self._stream_remaining: Dict[int, float] = {}
        # next expected arrival (the simulator/engine tells us)
        self.next_arrival_t: float = math.inf

    # ------------------------------------------------------------------
    # queue management
    # ------------------------------------------------------------------
    def annotate_stream(self, ops: Sequence[KernelOp]) -> None:
        """Compute per-op latest-start deadlines for one stream's program."""
        suffix = 0.0
        times = [self.cost.gemm_time(op.shape) for op in ops]
        for op, t in zip(reversed(list(ops)), reversed(times)):
            suffix += t
            # store latest start in deadline_t's shadow via attribute
            op.latest_start_t = op.deadline_t - suffix  # type: ignore[attr-defined]

    def push(self, ops: Sequence[KernelOp]) -> None:
        for op in ops:
            if not hasattr(op, "latest_start_t"):
                op.latest_start_t = op.deadline_t - self.cost.gemm_time(op.shape)  # type: ignore[attr-defined]
        self.ready.extend(ops)

    def pending(self) -> int:
        return len(self.ready)

    # ------------------------------------------------------------------
    # the decision procedure
    # ------------------------------------------------------------------
    def decide(self, now: float) -> Decision:
        if not self.ready:
            return Decision("idle")
        cfg = self.cfg
        target_tiles = cfg.target_tiles or self.cost.device.num_units

        # 1. EDF anchor: the op with the earliest latest-start
        anchor = min(self.ready, key=lambda o: o.latest_start_t)  # type: ignore[attr-defined]

        # 2. its zero-padding coalescing group among ready ops
        groups = group_ops_exact(self.ready)
        akey = next(k for k, v in groups.items() if anchor in v)
        group = groups[akey]
        # order group by urgency; anchor first
        group = sorted(group, key=lambda o: o.latest_start_t)  # type: ignore[attr-defined]
        group = group[: cfg.max_group]
        plan = self.coalescer.plan(group)

        # 3. stagger decision: is the group under-filling the device, and
        #    does the anchor have slack to wait for more arrivals?
        tiles = sum(self.cost.tiles(s, plan.block) for s in plan.shapes)
        slack = anchor.latest_start_t - now  # type: ignore[attr-defined]
        if (tiles < target_tiles and slack > 0
                and self.next_arrival_t < now + min(slack, cfg.max_wait_s)):
            # napkin check: modeled gain of one more same-shape problem
            probe = KernelOp(-1, -1, anchor.kind, anchor.shape)
            gain = self.coalescer.marginal_gain(group, probe)
            if gain > cfg.min_wait_gain_s:
                return Decision("wait",
                                wait_until=min(now + slack,
                                               self.next_arrival_t,
                                               now + cfg.max_wait_s))

        for op in plan.ops:
            self.ready.remove(op)
        return Decision("dispatch", plan=plan)

    # ------------------------------------------------------------------
    def drain(self, now: float = 0.0) -> List[SuperkernelPlan]:
        """Dispatch everything (no waiting) — used by tests and batch mode."""
        plans = []
        self.next_arrival_t = math.inf
        while self.ready:
            d = self.decide(now)
            assert d.kind == "dispatch" and d.plan is not None
            plans.append(d.plan)
            now += d.plan.est_time_s
        return plans
