"""Yi-9B — llama-architecture dense decoder with GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    arch_type="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=10000.0,
    source="arXiv:2403.04652",
)
