"""Granite-34B-Code — llama-architecture dense decoder, MQA (kv=1)
[arXiv:2405.04324]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    source="arXiv:2405.04324",
)
