"""Config registry: 10 assigned architectures + reduced smoke variants.

``get_config(arch_id)`` returns the exact assigned config; ``smoke_config``
returns a reduced variant of the same family (≤2 layers, d_model ≤ 512,
≤4 experts) used by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, MoEConfig, SSMConfig

from repro.configs.yi_9b import CONFIG as _yi_9b
from repro.configs.internvl2_2b import CONFIG as _internvl2_2b
from repro.configs.grok_1_314b import CONFIG as _grok_1_314b
from repro.configs.granite_34b import CONFIG as _granite_34b
from repro.configs.stablelm_12b import CONFIG as _stablelm_12b
from repro.configs.mamba2_2_7b import CONFIG as _mamba2_2_7b
from repro.configs.whisper_tiny import CONFIG as _whisper_tiny
from repro.configs.hymba_1_5b import CONFIG as _hymba_1_5b
from repro.configs.llama4_maverick_400b_a17b import CONFIG as _llama4
from repro.configs.gemma3_1b import CONFIG as _gemma3_1b

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _yi_9b,
        _internvl2_2b,
        _grok_1_314b,
        _granite_34b,
        _stablelm_12b,
        _mamba2_2_7b,
        _whisper_tiny,
        _hymba_1_5b,
        _llama4,
        _gemma3_1b,
    ]
}

ARCH_IDS = tuple(REGISTRY)

# (arch, shape) pairs excluded from the dry-run per DESIGN.md §6: long_500k
# requires sub-quadratic attention and is skipped for pure full-attention
# architectures (and for whisper's 448-position decoder family).
SKIPPED_PAIRS = frozenset(
    (arch, "long_500k")
    for arch in (
        "yi-9b",
        "granite-34b",
        "stablelm-12b",
        "internvl2-2b",
        "grok-1-314b",
        "whisper-tiny",
    )
)


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}"
        ) from None


def pair_is_supported(arch_id: str, shape_name: str) -> bool:
    return (arch_id, shape_name) not in SKIPPED_PAIRS


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    full = get_config(arch_id)
    kw = dict(
        name=full.name + "-smoke",
        num_layers=2,
        d_model=min(full.d_model, 128),
        vocab_size=min(full.vocab_size, 512),
    )
    if full.arch_type != "ssm":
        kw.update(
            num_heads=4,
            num_kv_heads=min(full.num_kv_heads, 2) if full.num_kv_heads > 1 else 1,
            d_ff=min(full.d_ff, 256),
            head_dim=32,
        )
    if full.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=min(full.moe.num_experts, 4),
            top_k=min(full.moe.top_k, 2),
        )
    if full.ssm is not None:
        kw["ssm"] = SSMConfig(
            d_state=min(full.ssm.d_state, 16),
            head_dim=32,
            expand=2,
            chunk_size=16,
        )
    if full.window_size:
        kw["window_size"] = 32
        kw["global_every"] = 2
    if full.arch_type == "audio":
        kw["num_encoder_layers"] = 2
        kw["encoder_seq_len"] = 24
    if full.arch_type == "vlm":
        kw["num_patch_tokens"] = 8
    return dataclasses.replace(full, **kw)


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "REGISTRY",
    "SKIPPED_PAIRS",
    "SSMConfig",
    "get_config",
    "pair_is_supported",
    "smoke_config",
]
