"""InternVL2-2B — InternViT frontend (stubbed) + InternLM2 backbone
[arXiv:2404.16821].

Per the assignment, the VLM entry specifies the transformer backbone only;
``input_specs()`` provides precomputed patch embeddings of the right shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    num_patch_tokens=256,
    source="arXiv:2404.16821",
)
