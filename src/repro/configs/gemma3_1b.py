"""Gemma-3-1B — dense decoder, 5:1 local:global attention, window 1024, 128k+
context [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    window_size=1024,
    global_every=6,
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
