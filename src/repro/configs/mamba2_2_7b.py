"""Mamba2-2.7B — attention-free SSD (state-space duality) stack
[arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
