"""Grok-1 (314B) — MoE with 8 experts, top-2 routing [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2),
    source="hf:xai-org/grok-1",
)
