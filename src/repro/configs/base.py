"""Base configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. The config is a
plain frozen dataclass (hashable, usable as a jit static argument) describing
the *transformer backbone* — modality frontends (ViT for VLM, conv/mel for
audio) are stubs per the assignment: ``input_specs()`` provides precomputed
patch/frame embeddings of the right shape.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for MoE layers."""

    num_experts: int
    top_k: int
    # capacity factor used when dispatching tokens to experts (train/prefill).
    capacity_factor: float = 1.25
    # weight of the auxiliary load-balancing loss.
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD — state space duality, arXiv:2405.21060) settings."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 64
    d_conv: int = 4  # depthwise conv width in the mamba block

    def num_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description for one assigned model.

    ``arch_type`` selects the block family:
      dense  — pre-norm decoder-only transformer (GQA/MQA attention)
      moe    — dense attention + MoE FFN every layer
      ssm    — attention-free Mamba-2 (SSD) stack
      hybrid — Hymba-style parallel attention + SSM heads in each layer
      vlm    — dense LLM backbone consuming stubbed patch embeddings
      audio  — Whisper-style encoder/decoder; conv/mel frontend stubbed
    """

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention locality -------------------------------------------------
    # window size for sliding-window/local layers (0 => all layers global).
    window_size: int = 0
    # pattern period P with one global layer per period (e.g. gemma3 is 6 with
    # 5 local : 1 global). 0 => all layers global.
    global_every: int = 0
    # --- optional sub-configs ------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- audio/vlm frontend stubs -------------------------------------------
    num_encoder_layers: int = 0           # audio (whisper) encoder depth
    encoder_seq_len: int = 0              # frames (audio) per the model card
    num_patch_tokens: int = 0             # vlm: patch embeddings per request
    # --- misc ----------------------------------------------------------------
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                      # citation from the assignment table

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:  # attention-free
            return 0
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the unembedding shards evenly over 16-way TP."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.arch_type == "audio"

    @property
    def has_moe(self) -> bool:
        return self.moe is not None

    @property
    def has_ssm(self) -> bool:
        return self.ssm is not None

    def layer_is_global(self, layer_idx: int) -> bool:
        """True if layer uses full (global) attention.

        With ``global_every == P``, the last layer of every period of P is
        global (gemma3: layers 5, 11, 17, 23 of 26; llama4: every 4th).
        """
        if self.window_size == 0 or self.global_every == 0:
            return True
        return (layer_idx % self.global_every) == (self.global_every - 1)

    def global_layer_flags(self) -> Tuple[bool, ...]:
        return tuple(self.layer_is_global(i) for i in range(self.num_layers))

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytical parameter count (embeddings included once if tied)."""
        d, dff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        if self.arch_type == "ssm":
            s = self.ssm or SSMConfig()
            d_inner = s.expand * d
            nheads = s.num_heads(d)
            # in_proj: d -> (2*d_inner + 2*n_groups*d_state + nheads); use
            # n_groups = 1 for simplicity.
            in_proj = d * (2 * d_inner + 2 * s.d_state + nheads)
            out_proj = d_inner * d
            conv = s.d_conv * (d_inner + 2 * s.d_state)
            per_layer = in_proj + out_proj + conv + 2 * d
            body = L * per_layer
        else:
            q = d * (self.num_heads * hd)
            kv = 2 * d * (self.num_kv_heads * hd)
            o = (self.num_heads * hd) * d
            attn = q + kv + o
            if self.has_moe:
                ffn = self.moe.num_experts * 3 * d * dff + d * self.moe.num_experts
            else:
                ffn = 3 * d * dff  # gate/up/down (SwiGLU)
            per_layer = attn + ffn + 2 * d
            if self.arch_type == "hybrid":
                s = self.ssm or SSMConfig(d_state=16)
                d_inner = s.expand * d
                nheads = s.num_heads(d)
                per_layer += d * (2 * d_inner + 2 * s.d_state + nheads) + d_inner * d
            body = L * per_layer
            if self.is_encdec:
                enc_per_layer = attn + 3 * d * dff + 2 * d
                cross = attn
                body += self.num_encoder_layers * enc_per_layer + L * cross
        emb = self.padded_vocab * d
        if not self.tie_embeddings:
            emb *= 2
        return body + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts FFNs)."""
        if not self.has_moe:
            return self.param_count()
        d, dff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (
            self.num_heads * hd
        ) * d
        ffn_active = self.moe.top_k * 3 * d * dff + d * self.moe.num_experts
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn_active + 2 * d) + emb


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch, kind) workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
