"""Hymba-1.5B — hybrid: parallel attention + mamba heads per layer
[arXiv:2411.13676].

Hymba uses sliding-window attention on most layers with three full-attention
(global) layers; we express that as window 1024 with one global layer per
~11-layer period (layers 10, 21 and the final block of the 32-layer stack).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    window_size=1024,
    global_every=11,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk_size=64),
    source="arXiv:2411.13676",
)
