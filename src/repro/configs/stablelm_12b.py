"""StableLM-2-12B — dense decoder with GQA [hf:stabilityai/stablelm-2-1_6b
family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    source="hf:stabilityai/stablelm-2-1_6b",
)
