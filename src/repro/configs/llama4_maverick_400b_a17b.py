"""Llama-4-Maverick (400B total / 17B active) — MoE, 128 experts top-1, early
fusion, chunked attention (iRoPE: 3 local : 1 global, chunk 8192)
[hf:meta-llama/Llama-4-Scout-17B-16E family]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    window_size=8192,
    global_every=4,
    moe=MoEConfig(num_experts=128, top_k=1),
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
