"""Whisper-tiny — encoder/decoder with conv/mel frontend (stubbed)
[arXiv:2212.04356].

The conv+mel frontend is a stub per the assignment: ``input_specs()`` provides
precomputed frame embeddings [batch, 1500, d_model] for the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    num_encoder_layers=4,
    encoder_seq_len=1500,
    source="arXiv:2212.04356",
)
