"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp


def coalesced_gemm_ref(a_packed: jax.Array, b_stacked: jax.Array,
                       group_ids: jax.Array, bm: int) -> jax.Array:
    """Reference for the grouped superkernel.

    a_packed: [M_pad, K] — problems concatenated along m (each problem's rows
    padded to a multiple of ``bm``); b_stacked: [G, K, N]; group_ids:
    [M_pad // bm] int32 mapping each m-tile to its problem.
    """
    M, K = a_packed.shape
    tiles = a_packed.reshape(M // bm, bm, K)
    b_per_tile = b_stacked[group_ids]                    # [T, K, N]
    out = jnp.einsum("tmk,tkn->tmn", tiles, b_per_tile,
                     preferred_element_type=jnp.float32)
    return out.reshape(M, b_stacked.shape[-1]).astype(a_packed.dtype)


def coalesced_gemv_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Batched matvec: x [G, K], w [G, K, N] -> [G, N]."""
    return jnp.einsum("gk,gkn->gn", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jax.Array:
    """Dense attention oracle. q,k,v: [B, H, S, D] -> [B, H, S, D]."""
    B, H, S, D = q.shape
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= cols <= rows
    if window > 0:
        ok &= cols > rows - window
    logits = jnp.where(ok[None, None], logits, -2.0e38)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
