"""Flash attention (causal, optional sliding window) as a Pallas TPU kernel.

Used by the long-context serving path: gemma3 / llama4 / hymba local layers
attend within a window, which bounds the per-token working set; the kernel
keeps a running (m, l, acc) online-softmax state in VMEM scratch and streams
K/V tiles through the innermost grid dimension.

Layout: q/k/v are [BH, S, D] (batch×heads flattened by ops.py). Grid is
(BH, S/bq, S/bkv) with the kv dimension 'arbitrary' (sequential) so the
scratch accumulator carries across kv tiles of one q tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

_NEG = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nkv: int, bq: int, bkv: int, causal: bool, window: int,
            scale: float):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # [bq, D]
    k = k_ref[0].astype(jnp.float32)              # [bkv, D]
    v = v_ref[0].astype(jnp.float32)              # [bkv, D]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    rows = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    cols = kv_idx * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jnp.ones((bq, bkv), bool)
    if causal:
        ok &= cols <= rows
    if window > 0:
        ok &= cols > rows - window
    s = jnp.where(ok, s, _NEG)

    m_prev = m_ref[...]                           # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)               # [bq, 1]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == nkv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal", "window",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 128, bkv: int = 128, causal: bool = True,
                    window: int = 0, interpret: bool = True) -> jax.Array:
    """q, k, v: [BH, S, D] -> [BH, S, D]."""
    BH, S, D = q.shape
    bq = min(bq, S)
    bkv = min(bkv, S)
    assert S % bq == 0 and S % bkv == 0, (S, bq, bkv)
    nkv = S // bkv
    scale = 1.0 / (D ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, nkv=nkv, bq=bq, bkv=bkv, causal=causal,
                          window=window, scale=scale),
        grid=(BH, S // bq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
