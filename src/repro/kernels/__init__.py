"""Pallas TPU kernels for the perf-critical compute the paper optimizes:
the coalesced (grouped) GEMM superkernel, the coalesced GEMV, and windowed
flash attention. Each has a pure-jnp oracle in ref.py; ops.py holds the
jit'd packing wrappers. Kernels are validated in interpret mode on CPU.
"""
from repro.kernels.coalesced_gemm import coalesced_gemm
from repro.kernels.coalesced_gemv import coalesced_gemv
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import (coalesced_matvec, execute_superkernel,
                               pack_problems, windowed_attention)

__all__ = [
    "coalesced_gemm", "coalesced_gemv", "flash_attention",
    "coalesced_matvec", "execute_superkernel", "pack_problems",
    "windowed_attention",
]
