"""Version compatibility for the Pallas TPU API.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and back
again across releases); the pinned JAX in this container only exposes the
``TPUCompilerParams`` spelling. ``tpu_compiler_params`` resolves whichever
class exists at import time so the kernels build against both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build a Pallas TPU compiler-params object under either JAX spelling."""
    return _COMPILER_PARAMS_CLS(**kwargs)
