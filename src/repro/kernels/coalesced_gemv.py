"""Coalesced matrix-vector superkernel (paper §5.3: RNN/LSTM inference).

Packs G decode-time matvecs — one per stream — into a single Pallas kernel.
Two regimes:

  * distinct weights (different tenants / different layers): batched GEMV,
    grid over (problem, n-tile), each step streams one (K × bn) weight panel;
  * shared weights (G streams of the SAME model+layer — the paper's RNN
    claim): the packer concatenates vectors into one [G, K] matrix and calls
    the plain GEMM path instead, loading the weight panel ONCE (see
    ops.coalesced_matvec which makes this dispatch decision).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [1, bk] @ [bk, bn] -> [1, bn]
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def coalesced_gemv(x: jax.Array, w: jax.Array, *, bn: int = 128,
                   bk: int = 512, interpret: bool = True) -> jax.Array:
    """x: [G, K] packed vectors; w: [G, K, N] per-problem weights -> [G, N]."""
    G, K = x.shape
    G2, K2, N = w.shape
    assert (G, K) == (G2, K2)
    bn = min(bn, N)
    bk = min(bk, K)
    assert N % bn == 0 and K % bk == 0, (N, bn, K, bk)
    nk = K // bk

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(G, N // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bk), lambda g, j, k: (g, k)),
            pl.BlockSpec((1, bk, bn), lambda g, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda g, j, k: (g, j)),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((G, N), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
