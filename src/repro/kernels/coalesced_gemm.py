"""The paper's superkernel, TPU-native: a grouped GEMM Pallas kernel.

One ``pallas_call`` executes G heterogeneous GEMM problems that the JIT
coalesced (paper §5.3 / Fig. 6). Problems are padded to a common (K, N)
envelope and concatenated along m; a scalar-prefetched ``group_ids`` vector
maps each m-tile to its weight matrix, so the B BlockSpec index_map selects
the right problem's operand per grid step — the TPU analogue of
``cublasSgemmBatched`` with *ragged* problem sizes.

VMEM tiling: (bm × bk) A panels, (bk × bn) B panels, one (bm × bn) fp32
accumulator scratch; the k grid dimension is innermost ("arbitrary"
semantics) and accumulates into scratch, so VMEM footprint is
bm·bk + bk·bn + bm·bn regardless of problem size — exactly the working-set
knob the co-tenancy autotuner (core/autotuner.py) tunes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _kernel(gid_ref, a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def coalesced_gemm(a_packed: jax.Array, b_stacked: jax.Array,
                   group_ids: jax.Array, *, bm: int = 128, bn: int = 128,
                   bk: int = 512, interpret: bool = True) -> jax.Array:
    """Run the grouped superkernel.

    a_packed:  [M_pad, K]    problems concatenated along m (rows padded per
                             problem to multiples of ``bm``; pad rows zero);
    b_stacked: [G, K, N]     per-problem weight envelopes (padded to common
                             K, N by the packer);
    group_ids: [M_pad // bm] int32 problem id per m-tile (scalar-prefetched).
    Returns [M_pad, N]; pad rows come back zero.
    """
    M, K = a_packed.shape
    G, K2, N = b_stacked.shape
    assert K == K2, (K, K2)
    assert M % bm == 0 and group_ids.shape == (M // bm,)
    bn = min(bn, N)
    bk = min(bk, K)
    assert N % bn == 0 and K % bk == 0, (N, bn, K, bk)
    nk = K // bk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, gid: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, gid: (gid[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, gid: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), a_packed.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(group_ids, a_packed, b_stacked)
