"""jit'd wrappers + host-side packing for the Pallas superkernels.

This is the layer the JIT engine (core/jit.py, serving/engine.py) calls:
``execute_superkernel`` takes a planned group of (activation, weight)
problems, pads them to the cluster envelope, packs, dispatches the right
Pallas kernel, and unpacks per-problem results. The functions here are the
**eager reference path**: every dispatch re-pads and re-stacks its weight
operands and pays exact max-(K, N) envelopes. The serving hot path goes
through ``core/dispatch.py``'s ``SuperkernelExecutor`` instead, which caches
packed weights persistently and buckets envelopes so steady-state ticks hit
JAX's compile cache; this module stays the bit-compatibility oracle those
fast paths are tested against.

Interpret mode and the compiled lane
------------------------------------
``REPRO_PALLAS_INTERPRET`` selects how every Pallas kernel in this package
executes (read at import into the module global ``INTERPRET``; callers that
need the current value at call time use ``interpret_default()`` and tests/
benches may flip it with ``set_interpret``):

  * unset / ``1`` (default) — ``pl.pallas_call(interpret=True)``: the kernel
    body runs as traced JAX ops on the host platform (CPU in this
    container). Correctness-exact, required wherever no TPU is attached.
  * ``0`` — the COMPILED lane: Mosaic-compiled kernels on a real TPU
    deployment. ``compiled_lane_available()`` probes whether the attached
    backend can actually compile a Pallas kernel (a CPU-only host cannot —
    jax raises "Only interpret mode is supported on CPU backend"); callers
    that were asked for the compiled lane but find it unavailable should
    fall back to interpret mode and SKIP wall-clock claims, not fail.

Compiled-lane policy: interpret mode pays a ~2 ms/grid-step host floor, so
interpret-mode WALL-CLOCK numbers only measure dispatch-layer overheads
(packing, retraces, cache traffic) — kernel-level effects (tile geometry,
VMEM residency) are invisible under the floor. Wall-clock comparisons of
*block configs* (the autotuner's subject) are therefore only meaningful on
the compiled lane at realistic dims (k, n ≥ 1024); everywhere else the
analytic cost model is the arbiter and interpret-mode runs gate
correctness (bit-identity, cache hit rates, retrace counts) only.
``benchmarks/compiled_autotune_bench.py`` implements exactly this split.

Compiled tiles must also fit VMEM: ``check_vmem`` raises a clear error
before dispatching a compiled kernel whose per-tile working set
(bm·bk + bk·bn input panels + fp32 bm·bn accumulator) exceeds the budget —
Mosaic would otherwise fail deep inside lowering. Interpret mode skips the
check (tiles are host arrays; nothing is resident).

Envelope bucketing policy (used by core/dispatch.py)
----------------------------------------------------
``envelope_bucket`` rounds a packed-dimension extent up to the next power of
two, floored at the 128-lane MXU tile — the same idea as ``prefill_bucket``
(core/jit.py) applied to the superkernel envelope. The jitted dispatch path
buckets every envelope extent — per-problem padded rows (multiples of
``bm``, total m-tiles a power of two) and the shared K and N via this
function; the problem/stacked-weight count G uses an UNfloored power-of-two
bucket (``dispatch._pow2`` — a 128 floor there would stack 128 full weight
copies per group) — so the number of distinct traced shapes stays finite
under group-shape churn and a steady-state tick never retraces. Bucket
padding is zeros: zero activation rows produce zero output rows (sliced
off), zero K columns/rows contribute exact ``+0.0`` terms to the fp32
accumulator, zero N columns and zero-padded weight slots are never read
back — so any bucket ≥ the exact envelope is correct. Note that bucketing
K beyond the eager path's exact 128-multiple envelope changes the fp32
contraction split (last-ulp reassociation); see the correctness contract
in core/dispatch.py.

The same zero-problem padding is what makes RAGGED groups safe — including
MoE expert-GEMM groups, whose per-problem row counts (the per-expert token
buffers, m = capacity C) vary with each tenant's batch and routing: every
problem's rows pad independently to ``bm`` multiples, the G bucket pads
with whole zero problems (outputs dropped), and a group mixing a tall
prefill GEMM, a 4-row decode GEMV and a C-row expert buffer shares one
traced signature per bucketed envelope. No kernel changes were needed for
non-dense tenants; only this padding contract.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.coalesced_gemm import coalesced_gemm
from repro.kernels.coalesced_gemv import coalesced_gemv
from repro.kernels.flash_attention import flash_attention
from repro.kernels import ref

# See "Interpret mode and the compiled lane" in the module docstring.
import os
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"

# VMEM budget the compiled-lane guard checks tiles against (TPU v5e:
# ~16 MiB/core). Overridable for smaller parts / headroom experiments.
VMEM_BYTES = int(os.environ.get("REPRO_VMEM_BYTES", 16 * 1024 * 1024))


def interpret_default() -> bool:
    """The CURRENT interpret-mode default. Prefer this over importing the
    ``INTERPRET`` name: an import binds the value once, silently ignoring a
    later ``set_interpret`` (the compiled-lane bench falls back to
    interpret mode at runtime when the probe fails)."""
    return INTERPRET


def set_interpret(value: bool) -> None:
    """Flip the process-wide interpret default (see ``interpret_default``).
    Layers that captured the old value in jit static args keep their
    compiled executables — flipping only affects dispatches that have not
    resolved their ``interpret=None`` yet."""
    global INTERPRET
    INTERPRET = bool(value)


def compiled_lane_available() -> bool:
    """Whether the attached jax backend can COMPILE a Pallas kernel.

    Probes once per process with a tiny ``coalesced_gemm`` at
    ``interpret=False``; CPU-only hosts (this container) raise, TPU hosts
    compile. Benches and parity tests use this to decide between running
    compiled-lane wall-clock claims and skipping them."""
    global _COMPILED_LANE
    if _COMPILED_LANE is None:
        try:
            a = jnp.zeros((8, 128), jnp.float32)
            b = jnp.zeros((1, 128, 128), jnp.float32)
            gid = jnp.zeros((1,), jnp.int32)
            jax.block_until_ready(coalesced_gemm(
                a, b, gid, bm=8, bn=128, bk=128, interpret=False))
            _COMPILED_LANE = True
        except Exception:           # noqa: BLE001 — any backend refusal
            _COMPILED_LANE = False
    return _COMPILED_LANE


_COMPILED_LANE: bool | None = None


def vmem_tile_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Per-tile working set of the coalesced GEMM kernels: the A and B
    input panels at the serving dtype plus the fp32 accumulator scratch."""
    return dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn


def check_vmem(bm: int, bn: int, bk: int, *, dtype_bytes: int = 4,
               interpret: bool, budget: int | None = None) -> None:
    """Compiled-lane VMEM guard (see the module docstring). No-op in
    interpret mode; raises ``ValueError`` before launching a compiled
    kernel whose tile cannot be resident."""
    if interpret:
        return
    budget = VMEM_BYTES if budget is None else budget
    need = vmem_tile_bytes(bm, bn, bk, dtype_bytes)
    if need > budget:
        raise ValueError(
            f"block (bm={bm}, bn={bn}, bk={bk}) needs {need} bytes of VMEM "
            f"> budget {budget}; tune under the budget (the autotuner's "
            f"candidate filter does) or raise REPRO_VMEM_BYTES")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def envelope_bucket(x: int, minimum: int = 128) -> int:
    """Power-of-two bucket for one packed-envelope extent (≥ ``minimum``).

    See "Envelope bucketing policy" in the module docstring; the jitted
    dispatch path (core/dispatch.py) applies this to K, N and G so the
    traced shape space stays finite over arbitrary group-shape churn.
    """
    assert x >= 1, x
    return max(minimum, 1 << (x - 1).bit_length())


@dataclasses.dataclass
class PackedGroup:
    """Host-side packing metadata for one superkernel dispatch."""
    a_packed: jax.Array           # [M_pad, K_pad]
    b_stacked: jax.Array          # [G, K_pad, N_pad]
    group_ids: jax.Array          # [M_pad // bm]
    row_slices: List[Tuple[int, int]]   # (start, real_m) per problem
    n_real: List[int]
    bm: int


def pack_problems(problems: Sequence[Tuple[jax.Array, jax.Array]], *,
                  bm: int = 128) -> PackedGroup:
    """Pad G (a [m,k], b [k,n]) problems to a common (K, N) envelope and
    concatenate the a's along m (per-problem m padded to a ``bm`` multiple)."""
    K = max(int(a.shape[1]) for a, _ in problems)
    N = max(int(b.shape[1]) for _, b in problems)
    K = _round_up(K, 128)
    N = _round_up(N, 128)
    a_parts, b_parts, gids, rows, n_real = [], [], [], [], []
    start = 0
    for g, (a, b) in enumerate(problems):
        m, k = a.shape
        m_pad = _round_up(m, bm)
        a_parts.append(jnp.pad(a, ((0, m_pad - m), (0, K - k))))
        b_parts.append(jnp.pad(b, ((0, K - b.shape[0]), (0, N - b.shape[1]))))
        gids.extend([g] * (m_pad // bm))
        rows.append((start, m))
        n_real.append(int(b.shape[1]))
        start += m_pad
    return PackedGroup(
        a_packed=jnp.concatenate(a_parts, axis=0),
        b_stacked=jnp.stack(b_parts, axis=0),
        group_ids=jnp.asarray(gids, jnp.int32),
        row_slices=rows, n_real=n_real, bm=bm)


def execute_superkernel(problems: Sequence[Tuple[jax.Array, jax.Array]], *,
                        bm: int = 128, bn: int = 128, bk: int = 512,
                        shared_operand: bool = False,
                        interpret: bool | None = None) -> List[jax.Array]:
    """Coalesce and execute G GEMM problems; returns per-problem outputs.

    shared_operand=True (all problems share one weight matrix — the RNN/
    decode lockstep case) concatenates activations into a single GEMM so the
    weights stream through VMEM once.
    """
    interpret = INTERPRET if interpret is None else interpret
    if shared_operand:
        b = problems[0][1]
        ms = [int(a.shape[0]) for a, _ in problems]
        x = jnp.concatenate([a for a, _ in problems], axis=0)
        m_pad = _round_up(x.shape[0], bm)
        k_pad = _round_up(b.shape[0], 128)
        n_pad = _round_up(b.shape[1], 128)
        xp = jnp.pad(x, ((0, m_pad - x.shape[0]), (0, k_pad - x.shape[1])))
        bp = jnp.pad(b, ((0, k_pad - b.shape[0]), (0, n_pad - b.shape[1])))
        check_vmem(bm, min(bn, n_pad), min(bk, k_pad),
                   dtype_bytes=xp.dtype.itemsize, interpret=interpret)
        out = coalesced_gemm(
            xp, bp[None], jnp.zeros((m_pad // bm,), jnp.int32),
            bm=bm, bn=min(bn, n_pad), bk=min(bk, k_pad), interpret=interpret)
        outs, s = [], 0
        for m in ms:
            outs.append(out[s:s + m, :b.shape[1]])
            s += m
        return outs
    packed = pack_problems(problems, bm=bm)
    check_vmem(bm, min(bn, packed.b_stacked.shape[-1]),
               min(bk, packed.b_stacked.shape[1]),
               dtype_bytes=packed.a_packed.dtype.itemsize,
               interpret=interpret)
    out = coalesced_gemm(packed.a_packed, packed.b_stacked, packed.group_ids,
                         bm=bm, bn=min(bn, packed.b_stacked.shape[-1]),
                         bk=min(bk, packed.b_stacked.shape[1]),
                         interpret=interpret)
    return [out[s:s + m, :n] for (s, m), n in
            zip(packed.row_slices, packed.n_real)]


def coalesced_matvec(xs: Sequence[jax.Array], ws: Sequence[jax.Array], *,
                     interpret: bool | None = None) -> List[jax.Array]:
    """G matvecs (x [k], w [k, n]). Dispatches the shared-weight GEMM path
    when every problem uses the same weight array."""
    interpret = INTERPRET if interpret is None else interpret
    shared = all(w is ws[0] for w in ws)
    if shared:
        outs = execute_superkernel(
            [(x[None, :], ws[0]) for x in xs], bm=8,
            shared_operand=True, interpret=interpret)
        return [o[0] for o in outs]
    K = _round_up(max(int(w.shape[0]) for w in ws), 128)
    N = _round_up(max(int(w.shape[1]) for w in ws), 128)
    xp = jnp.stack([jnp.pad(x, (0, K - x.shape[0])) for x in xs])
    wp = jnp.stack([jnp.pad(w, ((0, K - w.shape[0]), (0, N - w.shape[1])))
                    for w in ws])
    out = coalesced_gemv(xp, wp, bn=128, bk=min(512, K), interpret=interpret)
    return [out[i, :int(w.shape[1])] for i, w in enumerate(ws)]


def windowed_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       interpret: bool | None = None) -> jax.Array:
    """[B, H, S, D] flash attention via the Pallas kernel (flattens B×H)."""
    interpret = INTERPRET if interpret is None else interpret
    B, H, S, D = q.shape
    out = flash_attention(q.reshape(B * H, S, D), k.reshape(B * H, S, D),
                          v.reshape(B * H, S, D), causal=causal,
                          window=window, interpret=interpret)
    return out.reshape(B, H, S, D)
