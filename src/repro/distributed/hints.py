"""Activation-sharding hints.

Pure pjit propagation is ambiguous with FSDP-sharded weights: XLA may
satisfy a data-sharded contraction dim by resharding ACTIVATIONS to
feature-sharded (measured on gemma3: batch-replicated f32[256,4096,·]
intermediates) instead of all-gathering the weights (ZeRO-3). Production
JAX frameworks pin activation layouts with ``with_sharding_constraint`` at
block boundaries; this module provides that as an optional context so model
code stays mesh-agnostic (smoke tests run with no hints = no-op).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_STATE = threading.local()


def _current() -> Optional[Dict[str, object]]:
    return getattr(_STATE, "specs", None)


@contextlib.contextmanager
def activation_sharding(specs: Dict[str, object]):
    """specs: kind -> NamedSharding, e.g. {"btd": NamedSharding(mesh, P(dp))}."""
    prev = _current()
    _STATE.specs = specs
    try:
        yield
    finally:
        _STATE.specs = prev


def constrain(x: jax.Array, kind: str) -> jax.Array:
    specs = _current()
    if specs is None or kind not in specs:
        return x
    return jax.lax.with_sharding_constraint(x, specs[kind])


def static_hint(kind: str, default=None):
    """Non-array hints (e.g. 'moe_groups': the data-shard count the MoE
    dispatch should group by). Stored in the same context dict."""
    specs = _current()
    if specs is None:
        return default
    return specs.get(kind, default)
