"""Logical-axis sharding rules → NamedSharding pytrees.

Mesh: (data, model) single-pod / (pod, data, model) multi-pod
(launch/mesh.py).

Baseline scheme (uniform across all 10 assigned architectures):

  * FFN + vocab: tensor-parallel over "model" (Megatron column/row pair:
    w_gate/w_up shard d_ff, w_down shards it back with one psum; embedding
    and unembedding shard the vocab → vocab-parallel cross-entropy);
  * attention + SSM mixers: DATA-parallel compute, weights replicated over
    "model" and FSDP-sharded over the data/pod axes. Rationale: the assigned
    head counts (4, 6, 25, 40, 48 q-heads; 1–8 kv-heads) are mostly not
    16-divisible, and sharding the packed H·hd projection output makes the
    [B,S,H,hd] reshape cross shard boundaries — XLA then replicates whole
    activations mid-graph (measured: batch-replicated f32[256,4096,·]
    intermediates). Head-aligned TP for the divisible archs is a recorded
    §Perf hillclimb, not the baseline.
  * MoE experts: expert dim over the data/pod axes (expert parallelism)
    when divisible (llama4: 128/16 ✓), else FSDP over d_model (grok: 8 < 16);
    d_ff over "model" within each expert.
  * decode KV caches: SEQUENCE-sharded over "model" (uniform for every
    GQA/MQA config, no head divisibility constraints); batch over data when
    divisible; long_500k (batch 1) shards its 524k sequence over
    data×model. Softmax/psum over the sharded seq dim is inserted by SPMD.

Every assignment is divisibility-checked with graceful fallbacks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(mesh: Mesh, dim: int, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0


def _p(n_lead: int, *spec) -> P:
    return P(*([None] * n_lead + list(spec)))


# replicated-over-model, FSDP-over-data weights (attention + SSM mixers)
_DP_IN = {"wq", "wk", "wv", "in_proj"}    # [d_in, n]: FSDP d_in
_DP_OUT = {"wo", "out_proj"}              # [n, d_out]: FSDP d_out
# Megatron TP pair (dense FFN)
_TP_COL = {"w_gate", "w_up"}              # [d, ff]: FSDP d, TP ff
_TP_ROW = {"w_down"}                      # [ff, d]: TP ff, FSDP d


def _spec_for(path: str, shape, mesh: Mesh) -> P:
    fsdp = fsdp_axes(mesh)
    stacked = ("blocks" in path)
    n_lead = 1 if stacked else 0
    name = path.split("/")[-1]
    nd = len(shape)

    def fit(dim, axes):
        return axes if _fits(mesh, shape[dim], axes) else None

    if name == "embed":
        return P(fit(0, "model"), None)
    if name == "unembed":
        return P(fit(0, fsdp), fit(1, "model"))
    if name == "router":
        return _p(n_lead, None, None) if nd == n_lead + 2 else P(*[None] * nd)
    if name in ("w_gate", "w_up", "w_down") and nd == n_lead + 3:
        # MoE expert weights [L, E, a, b]: gate/up are [.., E, d, ff]
        # (TP the ff output), down is [.., E, ff, d] (TP the ff input).
        tp_dim = n_lead + (2 if name != "w_down" else 1)
        other = n_lead + (1 if name != "w_down" else 2)
        spec = [None] * nd
        spec[tp_dim] = fit(tp_dim, "model")
        if _fits(mesh, shape[n_lead], fsdp):
            spec[n_lead] = fsdp           # expert parallelism
        elif spec[other] is None:
            spec[other] = fit(other, fsdp)  # grok: FSDP d_model instead
        return P(*spec)
    if nd == n_lead + 2:
        i, o = n_lead, n_lead + 1
        if name in _DP_IN:
            return _p(n_lead, fit(i, fsdp), None)
        if name in _DP_OUT:
            return _p(n_lead, None, fit(o, fsdp))
        if name in _TP_COL:
            return _p(n_lead, fit(i, fsdp), fit(o, "model"))
        if name in _TP_ROW:
            return _p(n_lead, fit(i, "model"), fit(o, fsdp))
    # conv kernels, norms, biases, 1D per-layer params: replicate
    return P(*([None] * nd))


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def param_shardings(model, mesh: Mesh, rng=None) -> Any:
    """NamedSharding pytree matching ``model.init`` output (no allocation)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    shapes = jax.eval_shape(model.init, rng)
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append(_named(mesh, _spec_for(pstr, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(param_sh: Any, mesh: Mesh) -> Any:
    """OptState(step, mu, nu): moments follow the params; step replicated."""
    from repro.training.optimizer import OptState
    return OptState(
        step=_named(mesh, P()),
        mu=jax.tree_util.tree_map(lambda s: s, param_sh),
        nu=jax.tree_util.tree_map(lambda s: s, param_sh))


def batch_shardings(model, shape: InputShape, mesh: Mesh) -> Dict[str, Any]:
    """Shardings for the input batch of the step selected by shape.kind."""
    dp = fsdp_axes(mesh)
    B = shape.global_batch
    bspec = dp if _fits(mesh, B, dp) else (
        "data" if _fits(mesh, B, "data") else None)
    out: Dict[str, Any] = {}
    ins = model.input_specs(shape)
    for key, val in ins.items():
        if key == "cache":
            out[key] = cache_shardings(model, val, mesh, shape)
        elif key in ("tokens", "labels"):
            out[key] = _named(mesh, P(bspec, None))
        else:  # patch_embeds / frames: [B, T, d]
            out[key] = _named(mesh, P(bspec, None, None))
    return out


def cache_shardings(model, cache_shapes: Any, mesh: Mesh,
                    shape: InputShape) -> Any:
    """Decode-cache shardings: sequence over "model" (plus data when the
    batch can't use it), batch over data when divisible."""
    dp = fsdp_axes(mesh)
    B = shape.global_batch
    batch_ok = _fits(mesh, B, dp)
    bspec = dp if batch_ok else None
    seq_axes = ("model",) if batch_ok else tuple(list(dp) + ["model"])

    def seq_spec(dim: int):
        if _fits(mesh, dim, seq_axes):
            return seq_axes
        return "model" if _fits(mesh, dim, "model") else None

    def spec_leaf(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        nd = len(leaf.shape)
        if name in ("k", "v", "cross_k", "cross_v", "k_scale", "v_scale"):
            # [L, B, Hkv, S, hd]
            return _named(mesh, P(None, bspec, None,
                                  seq_spec(leaf.shape[3]), None))
        if name == "h":      # [L, B, H, P, N] — small recurrent state
            return _named(mesh, P(None, bspec, None, None, None))
        if name == "conv":   # [L, B, K-1, convdim]
            return _named(mesh, P(None, bspec, None, None))
        if name == "pos":
            return _named(mesh, P(bspec) if nd == 1 else P())
        return _named(mesh, P(*([None] * nd)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_leaf(p, l) for p, l in flat])
