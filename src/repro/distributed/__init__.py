"""Distribution layer: sharding rules + multi-device serving placement.

Two complementary halves:

  * ``sharding.py`` — logical-axis sharding rules → ``NamedSharding``
    pytrees for SPMD execution of ONE model over a mesh (TP FFN/vocab,
    expert-parallel MoE, sequence-sharded KV).
  * ``placement.py`` — tenant→device placement for the multi-tenant
    serving engine's modeled mesh: ``DeviceSet`` (ordered device
    profiles + memoized per-device cost models), ``PlacementPolicy``
    (greedy least-loaded bin-packing over modeled steady-state load,
    deterministic), and the collective-charge helpers that price MoE
    expert parallelism into the scheduler's EDF slack.

Placement model (what binds when):

  * **at admission** — a tenant's home device and expert span bind at its
    FIRST admission and never change; its weights, KV caches and every
    op it ever declares live on that device. Expert-parallel MoE tenants
    (mesh size divides the expert count — the same divisibility rule as
    ``sharding.py``) span the mesh with their expert weights and pay an
    all-to-all dispatch/combine charge per expert GEMM.
  * **per tick** — each device runs its own DISPATCH/WAIT decision, EDF
    anchor set and coalesced-group formation over its own op pool; ops
    never coalesce across devices (``clustering.coalesce_key`` leads
    with the device id) and the schedule certifier rejects any group
    that mixes devices or runs off its assignment (``PlacementHazard``).
"""
from repro.distributed.placement import (DeviceSet, PlacementPolicy,
                                         TenantPlacement,
                                         expert_collective_s,
                                         steady_state_load)
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        fsdp_axes, opt_state_shardings,
                                        param_shardings)

__all__ = [
    "DeviceSet", "PlacementPolicy", "TenantPlacement",
    "batch_shardings", "cache_shardings", "expert_collective_s",
    "fsdp_axes", "opt_state_shardings", "param_shardings",
    "steady_state_load",
]
