from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        fsdp_axes, opt_state_shardings,
                                        param_shardings)

__all__ = [
    "batch_shardings", "cache_shardings", "fsdp_axes",
    "opt_state_shardings", "param_shardings",
]
