"""Tenant→device placement for multi-device mesh serving.

The serving stack models N devices as N independent virtual timelines —
one ``OoOScheduler``/``Coalescer``/``JitSession`` per device, all sharing
one ``VLIWJit`` (plan, block-plan and packed-weight caches are keyed with
the device id). This module decides WHERE each tenant lives:

  * **binding time** — placement binds ONCE, at the tenant's first
    admission (its weights and KV caches are modeled as resident on the
    home device from then on). Per-tick decisions — DISPATCH/WAIT, EDF
    anchoring, coalesced-group formation — happen independently per
    device afterwards; nothing migrates mid-flight, and the schedule
    certifier's ``PlacementHazard`` + per-device conservation checks
    verify the binding held.
  * **policy** — greedy least-loaded bin-packing over the modeled
    steady-state decode load (``core.kernelspec.gemm_population`` ×
    ``CostModel.gemm_time``): each new tenant goes to the device with the
    smallest accumulated load, lowest index on ties. Admission order is
    deterministic (the engine walks the request trace), so the placement
    is reproducible — asserted in tests/test_multi_device.py. The greedy
    longest-processing-time argument bounds the resulting skew:
    ``max_load <= total/N + max_tenant_load`` (``load_bound``).
  * **expert span** — an expert-parallel MoE tenant may SPAN devices:
    when the mesh size divides its expert count (the same divisibility
    rule as ``distributed/sharding.py``'s expert-parallelism fallback),
    its expert weights are modeled as sharded across all N devices. Its
    ops still execute on the home device's timeline (the combine brings
    activations home), but every expert GEMM is charged an all-to-all
    dispatch+combine collective (``CostModel.all_to_all_time``) in its
    EDF slack and plan estimate — the capacity/latency trade of expert
    parallelism, visible to the scheduler instead of free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.configs.base import ModelConfig
from repro.core.costmodel import CostModel, Device, V100
from repro.core.kernelspec import gemm_population


@dataclasses.dataclass(frozen=True)
class TenantPlacement:
    """One tenant's binding: home device + expert-parallel span."""

    device: int        # home device — every op of the tenant runs here
    expert_span: int   # devices its MoE expert weights span (1 = local)


class DeviceSet:
    """The modeled mesh: an ordered list of ``Device`` profiles with one
    memoized ``CostModel`` per distinct device OBJECT.

    ``homogeneous(device, n)`` repeats the SAME ``Device`` instance, so
    all n mesh slots share one ``CostModel`` — deliberate: downstream
    caches key on cost-model identity (``ProgramTemplate``'s GEMM-suffix
    memo), and a homogeneous mesh must not thrash them with n distinct
    but equal models."""

    def __init__(self, devices: Sequence[Device]):
        assert devices, "a DeviceSet needs at least one device"
        self.devices: List[Device] = list(devices)
        self._cost_by_dev: Dict[int, CostModel] = {}

    @classmethod
    def homogeneous(cls, device: Device = V100, n: int = 1) -> "DeviceSet":
        return cls([device] * n)

    def __len__(self) -> int:
        return len(self.devices)

    def cost(self, d: int) -> CostModel:
        """The (memoized) cost model of mesh slot ``d``. Slots holding the
        identical ``Device`` object share one ``CostModel`` instance."""
        dev = self.devices[d]
        cm = self._cost_by_dev.get(id(dev))
        if cm is None:
            cm = CostModel(dev)
            self._cost_by_dev[id(dev)] = cm
        return cm

    def bind_cost(self, d: int, cost: CostModel) -> None:
        """Pin mesh slot ``d``'s cost model to an existing instance.

        Cost-model IDENTITY keys downstream memos (the program template's
        GEMM-suffix table), so a caller that already owns a ``CostModel``
        for slot d's device must bind it here rather than let ``cost()``
        mint a second equal-but-distinct one."""
        assert cost.device is self.devices[d], \
            "bound cost model must wrap mesh slot's own Device object"
        self._cost_by_dev[id(self.devices[d])] = cost


def steady_state_load(cost: CostModel, cfg: ModelConfig,
                      batch: int) -> float:
    """Modeled seconds per decode step of one tenant on ``cost``'s device:
    the per-layer GEMM population × depth, plus the unembed. This is the
    bin-packing weight — a static proxy for the tenant's timeline demand
    (real demand varies with batching/coalescing, but placement must bind
    before any of that happens)."""
    pop = gemm_population(cfg, max(1, batch))
    t = 0.0
    for tag, shape in pop:
        per_layer = tag != "unembed"
        t += cost.gemm_time(shape) * (cfg.num_layers if per_layer else 1)
    return t


def expert_collective_s(cost: CostModel, *, m: int, k: int,
                        dtype_bytes: int, layers: int, span: int) -> float:
    """Per-expert-GEMM collective charge for a device-spanning MoE tenant:
    the dispatch half scatters [m, k] activations to the expert shards and
    the combine half gathers the outputs back — one all-to-all over the
    round-trip bytes, repeated per scanned layer."""
    if span <= 1:
        return 0.0
    return cost.all_to_all_time(2.0 * layers * m * k * dtype_bytes, span)


class PlacementPolicy:
    """Greedy least-loaded tenant→device bin-packing (deterministic).

    ``place`` is idempotent per tenant name — the first call binds, every
    later call returns the existing binding (placement is an admission-
    time act; see the module docstring)."""

    def __init__(self, devices: DeviceSet):
        self.devices = devices
        self.load: List[float] = [0.0] * len(devices)
        self.assignments: Dict[str, TenantPlacement] = {}
        self._tenant_load: Dict[str, float] = {}

    # ------------------------------------------------------------------
    def expert_span(self, cfg: ModelConfig) -> int:
        """Mesh span of the tenant's expert weights: the full mesh when
        expert parallelism fits (mesh size divides the expert count —
        sharding.py's rule), else 1 (local, FSDP-style fallback)."""
        n = len(self.devices)
        if n > 1 and getattr(cfg, "has_moe", False) \
                and cfg.moe.num_experts % n == 0:
            return n
        return 1

    def place(self, name: str, cfg: ModelConfig,
              batch: int = 1) -> TenantPlacement:
        """Bind ``name`` to a home device (first call) or return its
        existing binding. Ties break to the lowest device index, so the
        placement of a fixed admission order is reproducible."""
        existing = self.assignments.get(name)
        if existing is not None:
            return existing
        d = min(range(len(self.devices)),
                key=lambda i: (self.load[i], i))
        w = steady_state_load(self.devices.cost(d), cfg, batch)
        self.load[d] += w
        self._tenant_load[name] = w
        placement = TenantPlacement(device=d,
                                    expert_span=self.expert_span(cfg))
        self.assignments[name] = placement
        return placement

    # ------------------------------------------------------------------
    def skew(self) -> float:
        """max/mean device load (1.0 = perfectly balanced)."""
        mean = sum(self.load) / len(self.load)
        return max(self.load) / mean if mean > 0 else 1.0

    def load_bound(self) -> float:
        """Greedy guarantee: no device's load exceeds the ideal share plus
        one tenant — ``total/N + max_tenant_load``. Tests assert
        ``max(load) <= load_bound()``."""
        if not self._tenant_load:
            return 0.0
        return (sum(self.load) / len(self.load)
                + max(self._tenant_load.values()))
