"""Sharding-aware checkpointing to flat .npz archives.

Leaves are keyed by their tree path; restore rebuilds the pytree against a
reference structure and (optionally) ``jax.device_put``s each leaf with the
target NamedSharding — so a checkpoint written on one mesh restores onto
another (the multi-pod resize path).
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import numpy as np


def _path_key(p) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree: Any):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        out["/".join(_path_key(p) for p in path)] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree: Any, step: Optional[int] = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = _flatten(tree)
    if step is not None:
        payload["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    np.savez(tmp, **payload)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)
    return path


def restore_checkpoint(path: str, reference: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``reference`` (shapes must match).

    ``shardings``: optional pytree (same structure) of NamedSharding to
    place each leaf on restore.
    """
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(reference)
        leaves = []
        for pathk, ref_leaf in flat:
            key = "/".join(_path_key(p) for p in pathk)
            arr = data[key]
            assert arr.shape == tuple(ref_leaf.shape), (key, arr.shape,
                                                        ref_leaf.shape)
            leaves.append(arr.astype(ref_leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def checkpoint_step(path: str) -> Optional[int]:
    with np.load(path) as data:
        if "__step__" in data:
            return int(data["__step__"])
    return None
