"""Synthetic LM data pipeline.

Deterministic, seekable, host-shardable token streams with learnable
structure: a mixture of (a) order-2 Markov chains over a Zipf-distributed
vocabulary and (b) verbatim repeats of earlier context — so a few hundred
training steps measurably reduce loss (examples/train_tiny.py). VLM/audio
configs get matching stub modality inputs (precomputed embeddings per the
assignment's frontend carve-out).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 256
    seed: int = 0
    zipf_a: float = 1.2
    repeat_prob: float = 0.3


class SyntheticLM:
    """Infinite iterator of {tokens, labels, (extras)} numpy batches."""

    def __init__(self, cfg: ModelConfig, data: DataConfig,
                 host_id: int = 0, num_hosts: int = 1):
        self.cfg = cfg
        self.data = data
        self.rng = np.random.default_rng(
            np.random.SeedSequence([data.seed, host_id]))
        self.num_hosts = num_hosts
        V = cfg.vocab_size
        # order-2 Markov structure: next token = f(prev, pos%P) + noise
        r = np.random.default_rng(data.seed + 7)
        self._mix = r.integers(0, V, size=(997,), dtype=np.int64)
        # Zipf weights over a capped support for cheap sampling
        support = min(V, 4096)
        w = 1.0 / np.arange(1, support + 1) ** data.zipf_a
        self._zipf_p = w / w.sum()
        self._support = support

    def _sequence(self) -> np.ndarray:
        d = self.data
        V = self.cfg.vocab_size
        n = d.seq_len + 1
        base = self.rng.choice(self._support, size=n, p=self._zipf_p)
        seq = np.empty(n, dtype=np.int64)
        seq[0] = base[0]
        for t in range(1, n):
            # deterministic structure most of the time, noise otherwise
            if self.rng.random() < 0.8:
                seq[t] = self._mix[(seq[t - 1] * 31 + t) % 997] % V
            else:
                seq[t] = base[t]
        if self.rng.random() < d.repeat_prob and n > 32:
            # verbatim repeat: copy an earlier span forward (induction heads)
            span = self.rng.integers(8, 17)
            src = self.rng.integers(0, n - 2 * span)
            dst = self.rng.integers(src + span, n - span)
            seq[dst:dst + span] = seq[src:src + span]
        return seq

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        d, cfg = self.data, self.cfg
        seqs = np.stack([self._sequence() for _ in range(d.batch_size)])
        batch: Dict[str, np.ndarray] = {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }
        if cfg.arch_type == "vlm":
            batch["patch_embeds"] = self.rng.standard_normal(
                (d.batch_size, cfg.num_patch_tokens, cfg.d_model),
                dtype=np.float32) * 0.02
        if cfg.is_encdec:
            batch["frames"] = self.rng.standard_normal(
                (d.batch_size, cfg.encoder_seq_len, cfg.d_model),
                dtype=np.float32) * 0.02
        return batch
