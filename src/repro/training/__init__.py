from repro.training.checkpoint import (checkpoint_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import (OptimizerConfig, OptState, adamw_update,
                                      init_opt_state, lr_at)
from repro.training.train_loop import make_train_step, train

__all__ = [
    "DataConfig", "OptState", "OptimizerConfig", "SyntheticLM",
    "adamw_update", "checkpoint_step", "init_opt_state", "lr_at",
    "make_train_step", "restore_checkpoint", "save_checkpoint", "train",
]
