"""AdamW with cosine schedule, warmup and global-norm clipping (pure JAX).

Optimizer state lives in fp32 regardless of param dtype (bf16 training).
State is a plain pytree so pjit shards it with the same rules as params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: OptimizerConfig, params: Any, grads: Any,
                 state: OptState) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
