"""Training loop: jitted step builder + driver.

``make_train_step`` returns a pure (params, opt_state, batch) -> updated
function suitable both for single-device smoke training and for pjit
lowering in the multi-pod dry-run (launch/dryrun.py passes in_shardings).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import (OptimizerConfig, OptState, adamw_update,
                                      init_opt_state)


def make_train_step(model: Model, opt_cfg: OptimizerConfig
                    ) -> Callable[[Any, OptState, Dict[str, jax.Array]],
                                  Tuple[Any, OptState, Dict[str, jax.Array]]]:
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def train(model: Model, data: Iterable[Dict[str, Any]], steps: int, *,
          opt_cfg: Optional[OptimizerConfig] = None,
          rng: Optional[jax.Array] = None,
          log_every: int = 10,
          checkpoint_path: Optional[str] = None,
          checkpoint_every: int = 0,
          log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
    """Smoke-scale training driver (single host)."""
    opt_cfg = opt_cfg or OptimizerConfig(total_steps=steps)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    params = model.init(rng)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    it = iter(data)
    losses = []
    t0 = time.perf_counter()
    for step in range(1, steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if log_every and step % log_every == 0:
            log_fn(f"step {step:5d} loss {losses[-1]:.4f} "
                   f"lr {float(metrics['lr']):.2e} "
                   f"gnorm {float(metrics['grad_norm']):.2f}")
        if checkpoint_path and checkpoint_every \
                and step % checkpoint_every == 0:
            save_checkpoint(checkpoint_path,
                            {"params": params, "opt": opt_state}, step=step)
    wall = time.perf_counter() - t0
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "wall_s": wall}
