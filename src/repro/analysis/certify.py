"""Schedule certifier: verify every OoO reordering a trace records.

The scheduler is free to reorder across streams, stagger dispatches and
coalesce cross-tenant groups — but only within the legality envelope the
runtime's invariants define. The certifier re-derives that envelope from a
``ScheduleTrace`` (see ``repro.core.schedtrace``) with no access to the
scheduler's internals, so a scheduler bug cannot vouch for itself:

  per-op checks (every dispatched op)
    * program order  — within one ``(stream, prog_uid)`` the ``seq``
      index is strictly increasing, and a stream never resumes a program
      it already moved past (two step programs of one stream must not
      interleave);
    * deadline       — within one program the deadline is constant and
      ``latest_start_t`` is non-decreasing in program order (the
      remaining GEMM-suffix critical path only shrinks).

  per-group checks (every coalesced superkernel)
    * placement      — every op in the group is assigned to the device
      the group dispatched on (a group can neither mix devices nor run
      on a device other than its ops' admission-time placement);
    * concurrency    — no two ops of one stream in one group (they would
      execute "simultaneously" against an intra-stream dependence);
    * KV aliasing    — no two ops whose programs declare overlapping
      KV-cache write sets (same owner + slot);
    * env aliasing   — no two ops writing the same key of the same env
      OBJECT (undeclared stages alias everything via ``"*"``);
    * operand identity — a shared-operand dispatch
      (``shared_weight_key``) requires every op's weight closure to have
      resolved to the identical array(s).

  whole-trace checks (run end)
    * conservation   — every admitted request retires, is evicted
      (exactly once), or surfaces unfinished; nothing is admitted or
      retired twice, and nothing retires/evicts/underfinishes without
      having been admitted.

``ScheduleCertifier`` is incremental — ``ServingEngine(certify=True)``
feeds it each tick's new ``DispatchRecord``s and it raises the concrete
``HazardViolation`` subclass at the offending dispatch. ``certify_trace``
is the batch wrapper the mutation tests use: full replay, optionally
collecting violations instead of raising.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Set, Tuple

from repro.core.schedtrace import (ConservationHazard, DeadlineHazard,
                                   DispatchRecord, EnvAliasHazard,
                                   HazardViolation, KVAliasHazard,
                                   OperandIdentityHazard, OpRecord,
                                   PlacementHazard, ProgramOrderHazard,
                                   ScheduleTrace)

# float tolerance for EDF monotonicity: latest_start_t moves by modeled
# gemm times (~1e-6 s), so absolute 1e-9 cleanly separates real
# regressions from accumulation noise
_TOL = 1e-9


class ScheduleCertifier:
    """Incremental legality checker over a stream of dispatch records.

    ``observe`` verifies one coalesced group against the state built from
    everything before it. With ``raise_on_violation`` (the engine's mode)
    the offending ``HazardViolation`` propagates at the exact tick it
    occurs; without it (the test-replay mode) violations accumulate in
    ``self.violations`` and checking continues.

    ``checks`` counts individual legality predicates evaluated — the
    gating benches assert ``violations == 0`` AND ``checks > 0``, because
    a certifier that silently checked nothing would otherwise read as a
    clean pass.
    """

    def __init__(self, raise_on_violation: bool = True):
        self.raise_on_violation = raise_on_violation
        self.checks = 0
        self.violations: List[HazardViolation] = []
        # program-order state
        self._active: Dict[int, int] = {}       # stream -> live prog_uid
        self._closed: Set[int] = set()          # prog uids moved past
        self._last_seq: Dict[int, int] = {}     # prog_uid -> last seq
        # deadline state
        self._deadline: Dict[int, float] = {}   # prog_uid -> deadline_t
        self._latest: Dict[int, float] = {}     # prog_uid -> last latest_start

    # ------------------------------------------------------------------
    def _emit(self, v: HazardViolation) -> None:
        self.violations.append(v)
        if self.raise_on_violation:
            raise v

    @staticmethod
    def _who(op: OpRecord) -> str:
        return (f"op {op.op_id} ({op.tag}, stream {op.stream}, "
                f"prog {op.prog_uid}, seq {op.seq})")

    # ------------------------------------------------------------------
    def observe(self, d: DispatchRecord) -> None:
        """Certify one dispatched superkernel group."""
        self._check_placement(d)
        self._check_group_concurrency(d)
        self._check_kv_alias(d)
        self._check_env_alias(d)
        self._check_operand_identity(d)
        for op in d.ops:
            self._check_program_order(op, d)
            self._check_deadline(op, d)

    # ------------------------------------------------------------------
    # group-level checks
    # ------------------------------------------------------------------
    def _check_placement(self, d: DispatchRecord) -> None:
        """Every op of the group must be assigned to the device the group
        dispatched on: one superkernel launches on one device, and an op
        must run where admission placed it (its weights live there)."""
        for op in d.ops:
            self.checks += 1
            if op.device != d.device:
                self._emit(PlacementHazard(
                    f"{self._who(op)} assigned to device {op.device} was "
                    f"dispatched in a device-{d.device} group at "
                    f"t={d.t:.6g}",
                    detail={"t": d.t, "op": op.op_id,
                            "devices": (op.device, d.device)}))

    def _check_group_concurrency(self, d: DispatchRecord) -> None:
        seen: Dict[int, OpRecord] = {}
        for op in d.ops:
            self.checks += 1
            prev = seen.get(op.stream)
            if prev is not None:
                self._emit(ProgramOrderHazard(
                    f"two ops of stream {op.stream} coalesced into one "
                    f"concurrent group at t={d.t:.6g}: "
                    f"{self._who(prev)} and {self._who(op)}",
                    detail={"t": d.t, "stream": op.stream,
                            "ops": (prev.op_id, op.op_id)}))
            seen[op.stream] = op

    def _check_kv_alias(self, d: DispatchRecord) -> None:
        owner: Dict[Tuple, OpRecord] = {}
        for op in d.ops:
            self.checks += 1
            for r in op.kv_writes:
                prev = owner.get(r)
                if prev is not None and prev.prog_uid != op.prog_uid:
                    self._emit(KVAliasHazard(
                        f"concurrent KV writers in one group at "
                        f"t={d.t:.6g}: {self._who(prev)} and "
                        f"{self._who(op)} both write {r!r}",
                        detail={"t": d.t, "resource": r,
                                "ops": (prev.op_id, op.op_id)}))
                owner[r] = op

    def _check_env_alias(self, d: DispatchRecord) -> None:
        # env keys only alias when the env OBJECT is shared; within one
        # dispatch both envs are live, so id() comparison is sound here
        by_env: Dict[int, List[OpRecord]] = {}
        for op in d.ops:
            self.checks += 1
            for prev in by_env.get(op.env_id, ()):
                if prev.prog_uid == op.prog_uid:
                    continue
                a, b = set(prev.env_writes), set(op.env_writes)
                shared = (a & b) or ({"*"} if ("*" in a or "*" in b) else
                                     set())
                if shared:
                    self._emit(EnvAliasHazard(
                        f"concurrent writers to shared env keys "
                        f"{sorted(shared, key=repr)!r} at t={d.t:.6g}: "
                        f"{self._who(prev)} and {self._who(op)}",
                        detail={"t": d.t, "keys": tuple(shared),
                                "ops": (prev.op_id, op.op_id)}))
            by_env.setdefault(op.env_id, []).append(op)

    def _check_operand_identity(self, d: DispatchRecord) -> None:
        if not d.shared_operand or not d.ops:
            return
        self.checks += 1
        ident = d.ops[0].weight_id
        for op in d.ops[1:]:
            if op.weight_id != ident:
                self._emit(OperandIdentityHazard(
                    f"shared-operand group at t={d.t:.6g} spans distinct "
                    f"weight arrays: {self._who(d.ops[0])} has identity "
                    f"{ident} but {self._who(op)} has {op.weight_id} "
                    f"(key {op.weight_key!r})",
                    detail={"t": d.t, "key": op.weight_key,
                            "ids": (ident, op.weight_id)}))

    # ------------------------------------------------------------------
    # per-op checks
    # ------------------------------------------------------------------
    def _check_program_order(self, op: OpRecord, d: DispatchRecord) -> None:
        if op.prog_uid == 0:        # raw op stream: no program identity
            return
        self.checks += 1
        active = self._active.get(op.stream)
        if active != op.prog_uid:
            if op.prog_uid in self._closed:
                self._emit(ProgramOrderHazard(
                    f"stream {op.stream} resumed program {op.prog_uid} "
                    f"after moving past it: {self._who(op)} dispatched at "
                    f"t={d.t:.6g} interleaves two step programs",
                    detail={"t": d.t, "stream": op.stream,
                            "prog_uid": op.prog_uid, "op": op.op_id}))
            if active is not None:
                self._closed.add(active)
            self._active[op.stream] = op.prog_uid
        last = self._last_seq.get(op.prog_uid)
        if last is not None and op.seq <= last:
            self._emit(ProgramOrderHazard(
                f"program order broken in prog {op.prog_uid}: "
                f"{self._who(op)} dispatched at t={d.t:.6g} after seq "
                f"{last} already ran",
                detail={"t": d.t, "prog_uid": op.prog_uid,
                        "seq": (last, op.seq), "op": op.op_id}))
        self._last_seq[op.prog_uid] = op.seq

    def _check_deadline(self, op: OpRecord, d: DispatchRecord) -> None:
        if op.prog_uid == 0:
            return
        self.checks += 1
        dl = self._deadline.get(op.prog_uid)
        if dl is not None and not (
                op.deadline_t == dl
                or (math.isinf(dl) and math.isinf(op.deadline_t))
                or abs(op.deadline_t - dl) <= _TOL):
            self._emit(DeadlineHazard(
                f"deadline drifted within prog {op.prog_uid}: "
                f"{self._who(op)} carries deadline {op.deadline_t!r} but "
                f"the program dispatched with {dl!r}",
                detail={"prog_uid": op.prog_uid,
                        "deadlines": (dl, op.deadline_t)}))
        self._deadline[op.prog_uid] = op.deadline_t
        prev = self._latest.get(op.prog_uid)
        if prev is not None and op.latest_start_t < prev - _TOL:
            self._emit(DeadlineHazard(
                f"latest_start_t regressed within prog {op.prog_uid}: "
                f"{self._who(op)} has latest_start {op.latest_start_t!r} "
                f"< predecessor's {prev!r} (the remaining critical path "
                f"can only shrink)",
                detail={"prog_uid": op.prog_uid,
                        "latest_start": (prev, op.latest_start_t)}))
        self._latest[op.prog_uid] = op.latest_start_t


def check_conservation(trace: ScheduleTrace,
                       raise_on_violation: bool = True
                       ) -> List[HazardViolation]:
    """Balance the request lifecycle: admitted = retired ∪ evicted ∪
    unfinished, with exactly-once admission/retirement.

    The sets may overlap — an evicted (SLO-demoted) request still
    executes opportunistically and retires — so this is a coverage check,
    not a partition check. Raw traces with no request records are
    vacuously balanced.
    """
    violations: List[HazardViolation] = []

    def emit(v: HazardViolation) -> None:
        violations.append(v)
        if raise_on_violation:
            raise v

    admits = [r for r, _ in trace.req_admits]
    admitted = set(admits)
    if len(admits) != len(admitted):
        dupes = sorted({r for r in admitted if admits.count(r) > 1})
        emit(ConservationHazard(
            f"requests admitted more than once: {dupes}",
            detail={"duplicates": dupes}))
    retires = [r for r, _ in trace.req_retires]
    retired = set(retires)
    if len(retires) != len(retired):
        dupes = sorted({r for r in retired if retires.count(r) > 1})
        emit(ConservationHazard(
            f"requests retired more than once: {dupes}",
            detail={"duplicates": dupes}))
    for name, s in (("retired", retired), ("evicted", set(trace.evicted)),
                    ("unfinished", set(trace.unfinished))):
        ghosts = sorted(s - admitted)
        if ghosts:
            emit(ConservationHazard(
                f"{name} requests never admitted: {ghosts}",
                detail={"set": name, "requests": ghosts}))
    lost = sorted(admitted - retired - set(trace.evicted)
                  - set(trace.unfinished))
    if lost:
        emit(ConservationHazard(
            f"admitted requests neither retired, evicted nor reported "
            f"unfinished: {lost}", detail={"requests": lost}))
    # per-device conservation (multi-device meshes): a request must retire
    # on the device it was admitted to — its KV cache and weights live
    # there, so a cross-device retire means the placement binding broke
    # mid-flight. Traces without device records are vacuously balanced.
    strays = sorted(
        (r, trace.req_devices[r], trace.retire_devices[r])
        for r in set(trace.req_devices) & set(trace.retire_devices)
        if trace.req_devices[r] != trace.retire_devices[r])
    if strays:
        emit(PlacementHazard(
            f"requests retired on a device other than their admission "
            f"placement (req, admitted, retired): {strays}",
            detail={"requests": strays}))
    return violations


def certify_trace(trace: ScheduleTrace, raise_on_violation: bool = True
                  ) -> ScheduleCertifier:
    """Full-trace replay: every dispatch through a fresh incremental
    certifier, then the whole-trace conservation check. Returns the
    certifier (``checks`` and ``violations`` populated); with
    ``raise_on_violation`` the first violation raises instead."""
    cert = ScheduleCertifier(raise_on_violation=raise_on_violation)
    for d in trace.dispatches:
        cert.observe(d)
    cert.checks += 1
    cert.violations.extend(
        check_conservation(trace, raise_on_violation=raise_on_violation))
    return cert
