"""Static dependence analysis for the OoO VLIW JIT.

The JIT reorders aggressively — EDF anchoring, stagger/WAIT, cross-tenant
superkernel coalescing, shared-operand collapsing — and every reordering
is only legal because of invariants the runtime maintains implicitly (one
live op per stream, private program envs, per-tenant KV slots, weight keys
that really name one array). This package makes those invariants EXPLICIT
and machine-checkable, in three passes:

``repro.analysis.depgraph``
    Static read/write-set dependence analysis per ``KernelProgram``.
    Every stage the builders in ``core/jit.py`` emit declares the env
    keys and KV-cache resources it reads and writes (the optional
    ``reads``/``writes`` fields on ``GemmStage``/``GlueStage``/
    ``StackedGemmStage``); an undeclared stage conservatively aliases
    everything. The pass yields RAW/WAR/WAW edges within a program —
    the true dependence structure the scheduler's program-order rule
    over-approximates — plus cross-program KV-slot/env aliasing
    constraints between tenants.

``repro.analysis.certify``
    Dynamic schedule certification. ``JitSession(record_trace=True)``
    records a ``ScheduleTrace`` (program admissions, stagger/WAIT
    events, per-superkernel group membership with per-op
    ``(stream, prog_uid, tag, seq)`` identity); the certifier replays it
    and re-derives the legality of every out-of-order decision.
    ``ServingEngine(certify=True)`` runs the incremental certifier per
    tick and raises on the first violation.

``repro.analysis.lint``
    Tracer-hazard linter: an AST pass over ``src/repro`` flagging the
    jit-tracing bug classes this codebase has actually hit — jitted
    closures capturing param arrays as baked constants (the last-ulp
    drift class), plan-cache key functions missing fields that
    ``ProgramTemplate.bind`` does not rebind (the stale-template class),
    and glue math bypassing the memoized ``_GLUE_JITS`` wrappers (the
    eager-vs-jitted bit-identity class). Runnable as
    ``python -m repro.analysis.lint [path] [--strict] [--json]``.

Hazard taxonomy (all subclasses of ``HazardViolation``; defined in
``repro.core.schedtrace`` so the runtime can raise them without importing
this package):

  * ``ProgramOrderHazard``    — per-stream program order broken: an op of
    one program ran before its predecessor (``seq`` regressed), a stream
    resumed a program it had already moved past, or two ops of one stream
    were packed into a single coalesced (concurrent) superkernel group.
  * ``KVAliasHazard``         — two ops in one group belong to programs
    whose declared KV write sets overlap (same cache owner + slot):
    concurrent writers to one KV row.
  * ``EnvAliasHazard``        — two ops in one group write the same key
    of the SAME program environment object (program envs are supposed to
    be private; a shared env dict aliases every key in it).
  * ``OperandIdentityHazard`` — a shared-operand dispatch
    (``clustering.shared_weight_key``) packed ops whose weight closures
    resolved to DIFFERENT arrays: the single weight load would silently
    serve the wrong tenant. Checked both statically by the certifier and
    at runtime by ``SuperkernelExecutor.execute``.
  * ``DeadlineHazard``        — EDF bookkeeping broke monotonicity:
    within one program the deadline must stay constant and
    ``latest_start_t`` must be non-decreasing in program order (the
    remaining GEMM-suffix critical path only shrinks as pc advances).
  * ``ConservationHazard``    — request accounting does not balance:
    every admitted request must retire, be evicted (exactly once), or
    surface in ``ServeReport.unfinished``; no request may be admitted or
    retired twice, nor retire/evict without admission.
"""
from repro.core.schedtrace import (ConservationHazard, DeadlineHazard,
                                   DispatchRecord, EnvAliasHazard,
                                   HazardViolation, KVAliasHazard,
                                   OperandIdentityHazard, OpRecord,
                                   ProgramAdmit, ProgramOrderHazard,
                                   ScheduleTrace)
from repro.analysis.certify import (ScheduleCertifier, certify_trace,
                                    check_conservation)
from repro.analysis.depgraph import (DepEdge, DepGraph, build_depgraph,
                                     cross_program_conflicts, stage_access)

__all__ = [
    "HazardViolation", "ProgramOrderHazard", "KVAliasHazard",
    "EnvAliasHazard", "OperandIdentityHazard", "DeadlineHazard",
    "ConservationHazard", "ScheduleTrace", "OpRecord", "DispatchRecord",
    "ProgramAdmit", "ScheduleCertifier", "certify_trace",
    "check_conservation", "DepEdge", "DepGraph", "build_depgraph",
    "cross_program_conflicts", "stage_access",
]
