"""Static read/write-set dependence graph per ``KernelProgram``.

Every stage the template builders emit declares its access sets (the
``reads``/``writes`` fields on ``GemmStage``/``GlueStage``/
``StackedGemmStage``): env keys like ``"h2"`` or ``("moe_act", e)``, plus
the reserved ``"cache"``/``"new_layers"`` resources for stages touching KV
state. ``None`` means UNDECLARED — the analysis must assume the stage
aliases everything, which serializes it against every neighbor (the
conservative wildcard ``"*"``).

The pass runs last-writer/readers-since bookkeeping over the stage list in
program order and yields the classic dependence edges:

  * RAW — stage j reads a key stage i last wrote (true dependence);
  * WAW — stage j overwrites a key stage i last wrote;
  * WAR — stage j overwrites a key stage i read since its last write
    (anti-dependence).

This is the ground truth the scheduler's program-order rule (one live op
per stream, stages issue strictly in ``pc`` order) over-approximates: the
certifier enforces total per-program order, and this graph proves which of
those orderings are actually load-bearing. It is also the review tool for
the declared sets themselves — a stage whose declared reads can never be
produced (no upstream writer and not a bind-time env binding) is a
declaration bug, surfaced by ``DepGraph.unsourced_reads``.

Cross-program constraints are simpler than intra-program ones — programs
share no env by construction — so ``cross_program_conflicts`` reduces to
declared-KV-slot overlap and env-object identity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

# the conservative wildcard: an undeclared stage reads and writes "*"
ALIAS_ALL: Tuple = ("*",)

# env keys bound by ProgramTemplate.bind (or its env_extra) rather than
# written by an upstream stage — legitimate sources for a first read
BIND_TIME_KEYS = frozenset({"tokens", "cache", "new_layers", "real_len",
                            "slot", "req"})


def stage_access(stage: Any) -> Tuple[Tuple, Tuple]:
    """The (reads, writes) access sets of one stage, conservatively
    widened: a ``None`` (undeclared) set becomes the wildcard ``("*",)``.
    Works on any stage flavor — the fields are read via ``getattr`` so
    raw/foreign stage objects degrade to alias-everything instead of
    raising."""
    reads = getattr(stage, "reads", None)
    writes = getattr(stage, "writes", None)
    return (tuple(reads) if reads is not None else ALIAS_ALL,
            tuple(writes) if writes is not None else ALIAS_ALL)


def _stage_label(i: int, stage: Any) -> str:
    tag = getattr(stage, "tag", None)
    if tag:
        return f"{i}:{tag}"
    fn = getattr(stage, "fn", None)
    name = getattr(fn, "__name__", type(stage).__name__)
    return f"{i}:{name}"


@dataclasses.dataclass(frozen=True)
class DepEdge:
    """One dependence edge: stage ``dst`` must not run before ``src``."""

    kind: str                      # "RAW" | "WAR" | "WAW"
    src: int                       # stage index
    dst: int
    resource: Any                  # the aliased key ("*" for conservative)


@dataclasses.dataclass
class DepGraph:
    """The dependence structure of one program's stage list."""

    labels: List[str]              # one per stage, index-aligned
    edges: List[DepEdge]
    conservative: List[int]        # indices of undeclared (wildcard) stages
    # declared reads with no upstream writer and no bind-time binding —
    # either a declaration bug or a genuinely dynamic env protocol
    unsourced_reads: List[Tuple[int, Any]]

    def edges_between(self, src: int, dst: int) -> List[DepEdge]:
        return [e for e in self.edges if e.src == src and e.dst == dst]

    def predecessors(self, i: int) -> Set[int]:
        return {e.src for e in self.edges if e.dst == i}


def build_depgraph(program_or_stages: Any) -> DepGraph:
    """Build the RAW/WAR/WAW graph for a ``KernelProgram`` (or template,
    or bare stage list) by forward last-writer analysis.

    Wildcard semantics: a ``"*"`` read touches every key seen so far; a
    ``"*"`` write clobbers every key (it becomes the last writer of the
    whole env), so undeclared stages act as full barriers.
    """
    stages = getattr(program_or_stages, "stages", program_or_stages)
    labels = [_stage_label(i, st) for i, st in enumerate(stages)]
    edges: Set[DepEdge] = set()
    conservative: List[int] = []
    unsourced: List[Tuple[int, Any]] = []

    last_writer: Dict[Any, int] = {}
    readers_since: Dict[Any, List[int]] = {}
    star_writer: Optional[int] = None      # last "*"-writing stage
    universe: Set[Any] = set()

    def latest_writer(key: Any) -> Optional[int]:
        w = last_writer.get(key)
        if star_writer is None:
            return w
        return star_writer if w is None else max(w, star_writer)

    for i, st in enumerate(stages):
        reads, writes = stage_access(st)
        star_r, star_w = "*" in reads, "*" in writes
        if star_r or star_w:
            conservative.append(i)
        eff_reads = set(universe) if star_r else \
            {k for k in reads if k != "*"}
        eff_writes = (set(universe) | {k for k in writes if k != "*"}) \
            if star_w else {k for k in writes if k != "*"}

        for k in sorted(eff_reads, key=repr):
            w = latest_writer(k)
            if w is not None:
                edges.add(DepEdge("RAW", w, i, k))
            elif k not in BIND_TIME_KEYS and not star_r:
                unsourced.append((i, k))
        for k in sorted(eff_writes, key=repr):
            w = latest_writer(k)
            if w is not None:
                edges.add(DepEdge("WAW", w, i, k))
            floor = -1 if w is None else w
            for r in readers_since.get(k, ()):
                if r > floor and r != i:
                    edges.add(DepEdge("WAR", r, i, k))

        # update state AFTER computing this stage's edges
        for k in eff_reads:
            readers_since.setdefault(k, []).append(i)
        for k in eff_writes:
            last_writer[k] = i
            readers_since[k] = []
        if star_w:
            star_writer = i
            readers_since = {}
        universe |= eff_reads | eff_writes

    ordered = sorted(edges, key=lambda e: (e.dst, e.src, e.kind, repr(e.resource)))
    return DepGraph(labels=labels, edges=ordered,
                    conservative=conservative, unsourced_reads=unsourced)


def cross_program_conflicts(a: Any, b: Any) -> List[Tuple[str, Any]]:
    """Aliasing constraints between two programs' declared footprints —
    the resources that make it ILLEGAL to pack ops of both programs into
    one concurrent superkernel group.

    Programs have private envs by construction, so only two channels can
    alias: declared KV-cache rows (``KernelProgram.kv_writes`` overlap —
    two writers to one owner+slot) and a literally shared env object
    (``a.env is b.env`` — every key aliases). Returns ``("kv", resource)``
    / ``("env", key)`` pairs; empty means the pair is freely coalescible.
    """
    conflicts: List[Tuple[str, Any]] = []
    akv = set(getattr(a, "kv_writes", ()) or ())
    bkv = set(getattr(b, "kv_writes", ()) or ())
    for r in sorted(akv & bkv, key=repr):
        conflicts.append(("kv", r))
    aenv, benv = getattr(a, "env", None), getattr(b, "env", None)
    if aenv is not None and aenv is benv:
        awr: Set[Any] = set()
        bwr: Set[Any] = set()
        for st in getattr(a, "stages", ()):
            awr |= set(stage_access(st)[1])
        for st in getattr(b, "stages", ()):
            bwr |= set(stage_access(st)[1])
        shared = (awr & bwr) or {"*"}
        for k in sorted(shared, key=repr):
            conflicts.append(("env", k))
    return conflicts
