"""Tracer-hazard linter: AST checks for the jit-tracing bug classes the
OoO JIT codebase has actually hit.

Three rules, each named after the failure it prevents:

``TH001`` — jitted closure captures an array-derived value as a constant.
    XLA codegens arrays EMBEDDED in a jitted function (closure captures)
    differently than arrays passed as traced arguments — last-ulp FMA and
    fusion differences — and silently pins the captured buffer alive.
    The stacked-template scan bodies were bitten by exactly this: every
    per-layer param must enter the scan as an ``xs`` argument, never a
    closure. The rule flags any jit-rooted function, nested inside
    another function, whose free variables resolve to an enclosing
    function's *array-derived* bindings (seeded by parameters named
    ``params``/``*_p`` and propagated through subscripts, attributes,
    calls and tree maps). Module-level bindings are exempt — they are
    deliberate (memoized weights, static tables).

``TH002`` — plan-cache key function omits a field ``bind()`` cannot fix.
    ``ProgramTemplate.bind`` rebinds only per-step env state; everything
    else a template closes over must be captured by its plan-cache key
    or a stale template silently serves the wrong closures. Key
    functions (``*_cache_key``) must reference the known-irreplaceable
    ingredients: object identity (``id(``), dtype (``.dtype``), cache
    geometry (``.shape``) and the emission regime (``"stacked"``).

``TH003`` — raw glue math called outside a jitted context.
    Eager execution of the attention/MoE/SSM glue helpers computes
    different last-ulp bits than the same helper inside a jitted program
    (the reason ``_GLUE_JITS`` exists). Direct calls to the raw helpers
    are only legal inside a jit-rooted function chain (the closure some
    ``jax.jit`` call roots, including jit factories) or in the helper's
    defining module (the analytic baseline path).

Jit-rootedness is derived per module: ``@jax.jit`` /
``functools.partial(jax.jit, ...)`` decorations, ``jax.jit(name)`` /
``jax.jit(lambda ...)`` call sites, and the factory pattern — a function
``g`` with ``jax.jit(g(...))`` somewhere roots every function ``g``
returns. Resolution is per-module and name-based, deliberately
conservative in both directions for a lint (not a verifier).

Run as::

    python -m repro.analysis.lint [paths...] [--strict] [--json]

with no paths it lints the whole ``repro`` package. ``--strict`` exits
nonzero on any finding (the CI gate); ``--json`` emits machine-readable
findings.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# the eager/jitted bit-identity frontier: raw math helpers whose results
# differ in the last ulp between eager and traced execution (TH003)
RAW_GLUE_HELPERS = frozenset({
    "_gqa_decode_attend", "_causal_prefill_attend",   # core/jit.py
    "decode_core",                                    # models/ssm.py
    "route", "dispatch_tokens", "combine_tokens",     # models/moe.py
})

# what a template plan-cache key function must visibly capture (TH002)
CACHE_KEY_INGREDIENTS = (
    ("id(", "object identity (id(...))"),
    (".dtype", "dtype"),
    (".shape", "cache geometry (.shape)"),
    ("stacked", "emission regime (\"stacked\")"),
)

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


@dataclasses.dataclass
class Finding:
    code: str                      # "TH001" | "TH002" | "TH003"
    path: str
    line: int
    symbol: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} [{self.symbol}] " \
               f"{self.message}"


# ---------------------------------------------------------------------------
# AST utilities
# ---------------------------------------------------------------------------

def _is_jax_jit(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "jit"
            and isinstance(node.value, ast.Name) and node.value.id == "jax")


def _is_partial_jax_jit(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call) or not node.args:
        return False
    f = node.func
    name_ok = (isinstance(f, ast.Name) and f.id == "partial") or \
        (isinstance(f, ast.Attribute) and f.attr == "partial")
    return name_ok and _is_jax_jit(node.args[0])


def _arg_names(node: ast.AST) -> List[str]:
    a = node.args
    args = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    if a.vararg:
        args.append(a.vararg)
    if a.kwarg:
        args.append(a.kwarg)
    return [x.arg for x in args]


def _target_names(target: ast.AST) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


def _bound_names(fn: ast.AST) -> Set[str]:
    """Every name bound anywhere inside ``fn``'s subtree (params,
    assignments, loop/with/except targets, defs, imports) — the
    complement of the free-variable set."""
    bound: Set[str] = set(_arg_names(fn))
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(node.name)
            bound.update(_arg_names(node))
        elif isinstance(node, ast.Lambda):
            bound.update(_arg_names(node))
        elif isinstance(node, ast.ClassDef):
            bound.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                bound.update(_target_names(t))
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            bound.update(_target_names(node.target))
        elif isinstance(node, ast.NamedExpr):
            bound.update(_target_names(node.target))
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            bound.update(_target_names(node.target))
        elif isinstance(node, ast.comprehension):
            bound.update(_target_names(node.target))
        elif isinstance(node, ast.withitem) and node.optional_vars:
            bound.update(_target_names(node.optional_vars))
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
        elif isinstance(node, ast.Import):
            for al in node.names:
                bound.add(al.asname or al.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for al in node.names:
                bound.add(al.asname or al.name)
    return bound


def _free_names(fn: ast.AST) -> Set[str]:
    loads = {n.id for n in ast.walk(fn)
             if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
    return loads - _bound_names(fn)


def _own_statements(fn: ast.AST) -> Iterable[ast.stmt]:
    """Statements in ``fn``'s own scope: recurse through control flow but
    never into nested function/class definitions."""
    def walk(body: Sequence[ast.stmt]) -> Iterable[ast.stmt]:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            yield stmt
            for field in ("body", "orelse", "finalbody"):
                yield from walk(getattr(stmt, field, ()) or ())
            for h in getattr(stmt, "handlers", ()) or ():
                yield from walk(h.body)
    yield from walk(getattr(fn, "body", ()) if not
                    isinstance(fn, ast.Lambda) else ())


def _derived_names(fn: ast.AST) -> Set[str]:
    """Array-derived bindings of one function scope: parameters named
    ``params``/``*_p`` seed the set; assignments whose value references a
    derived name (subscripts, attributes, calls — tree_map included —
    and containers) propagate it forward. Two passes close the common
    chains without a full fixpoint."""
    derived = {a for a in _arg_names(fn)
               if a == "params" or a.endswith("_p")}
    if isinstance(fn, ast.Lambda):
        return derived
    for _ in range(2):
        for stmt in _own_statements(fn):
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                targets, value = [stmt.target], stmt.value
            else:
                continue
            if value is None:
                continue
            refs = {n.id for n in ast.walk(value)
                    if isinstance(n, ast.Name)
                    and isinstance(n.ctx, ast.Load)}
            if refs & derived:
                for t in targets:
                    derived.update(_target_names(t))
    return derived


# ---------------------------------------------------------------------------
# per-module analysis
# ---------------------------------------------------------------------------

class _Module:
    def __init__(self, path: Path, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.parents: Dict[ast.AST, ast.AST] = {
            child: parent for parent in ast.walk(tree)
            for child in ast.iter_child_nodes(parent)}
        self.functions = [n for n in ast.walk(tree)
                          if isinstance(n, _FUNC_NODES)]
        self.top_level_defs = self._top_level_defs()
        self.rooted = self._jit_rooted()

    def _top_level_defs(self) -> Set[str]:
        names: Set[str] = set()
        for stmt in self.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names.add(stmt.name)
            elif isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    names.update(_target_names(t))
        return names

    def enclosing_chain(self, node: ast.AST) -> List[ast.AST]:
        """Enclosing function nodes, innermost first (node excluded)."""
        chain: List[ast.AST] = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, _FUNC_NODES):
                chain.append(cur)
            cur = self.parents.get(cur)
        return chain

    def _jit_rooted(self) -> Set[ast.AST]:
        """Function nodes some ``jax.jit`` call (transitively) roots."""
        rooted: Set[ast.AST] = set()
        rooted_names: Set[str] = set()
        factory_names: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and _is_jax_jit(node.func) \
                    and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Name):
                    rooted_names.add(arg.id)
                elif isinstance(arg, ast.Lambda):
                    rooted.add(arg)
                elif isinstance(arg, ast.Call) \
                        and isinstance(arg.func, ast.Name):
                    factory_names.add(arg.func.id)
        for fn in self.functions:
            if isinstance(fn, ast.Lambda):
                continue
            if fn.name in rooted_names:
                rooted.add(fn)
            for dec in fn.decorator_list:
                if _is_jax_jit(dec) or _is_partial_jax_jit(dec):
                    rooted.add(fn)
        # factory pattern: jax.jit(g(...)) roots whatever g returns
        for fn in self.functions:
            if isinstance(fn, ast.Lambda) or fn.name not in factory_names:
                continue
            returned: Set[str] = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Return) and node.value is not None:
                    if isinstance(node.value, ast.Name):
                        returned.add(node.value.id)
                    elif isinstance(node.value, ast.Lambda):
                        rooted.add(node.value)
            for nested in ast.walk(fn):
                if isinstance(nested, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) \
                        and nested.name in returned:
                    rooted.add(nested)
        return rooted


def _fn_name(fn: ast.AST) -> str:
    return getattr(fn, "name", "<lambda>")


def _check_th001(mod: _Module) -> List[Finding]:
    findings: List[Finding] = []
    derived_memo: Dict[ast.AST, Set[str]] = {}
    for fn in mod.rooted:
        chain = mod.enclosing_chain(fn)
        if not chain:
            continue               # module-level jit roots are deliberate
        for name in sorted(_free_names(fn)):
            for scope in chain:
                bound = _arg_names(scope) if isinstance(scope, ast.Lambda) \
                    else sorted(_bound_names(scope))
                if name not in bound:
                    continue
                if scope not in derived_memo:
                    derived_memo[scope] = _derived_names(scope)
                if name in derived_memo[scope]:
                    findings.append(Finding(
                        "TH001", str(mod.path), fn.lineno, _fn_name(fn),
                        f"jit-rooted function closes over array-derived "
                        f"'{name}' from enclosing '{_fn_name(scope)}' — "
                        f"XLA bakes it in as a constant (last-ulp drift, "
                        f"pinned buffer); pass it as a traced argument"))
                break              # name resolved at the nearest binder
    return findings


def _check_th002(mod: _Module) -> List[Finding]:
    findings: List[Finding] = []
    for fn in mod.functions:
        if isinstance(fn, ast.Lambda) or not fn.name.endswith("_cache_key"):
            continue
        src = ast.unparse(fn)
        missing = [label for needle, label in CACHE_KEY_INGREDIENTS
                   if needle not in src]
        if missing:
            findings.append(Finding(
                "TH002", str(mod.path), fn.lineno, fn.name,
                f"plan-cache key function omits field(s) bind() does not "
                f"rebind: {', '.join(missing)} — a stale template would "
                f"silently serve the wrong closures"))
    return findings


def _check_th003(mod: _Module) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        callee = f.id if isinstance(f, ast.Name) else \
            f.attr if isinstance(f, ast.Attribute) else None
        if callee not in RAW_GLUE_HELPERS:
            continue
        if callee in mod.top_level_defs:
            continue               # the defining module's analytic path
        chain = mod.enclosing_chain(node)
        if any(fn in mod.rooted for fn in chain):
            continue               # inside a jit-rooted closure chain
        where = _fn_name(chain[0]) if chain else "<module>"
        findings.append(Finding(
            "TH003", str(mod.path), node.lineno, where,
            f"raw glue helper '{callee}' called eagerly (outside any "
            f"jit-rooted chain) — route it through the memoized "
            f"_GLUE_JITS wrappers for eager/jitted bit-identity"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_file(path: Path) -> List[Finding]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding("TH000", str(path), e.lineno or 0, "<parse>",
                        f"syntax error: {e.msg}")]
    mod = _Module(path, tree)
    findings = _check_th001(mod) + _check_th002(mod) + _check_th003(mod)
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))


def lint_paths(paths: Sequence[Path]) -> List[Finding]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_file(f))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Tracer-hazard linter (TH001 jit-closure capture, "
                    "TH002 cache-key completeness, TH003 eager raw-glue "
                    "calls).")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the repro "
                         "package)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any finding is reported")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit machine-readable JSON findings")
    args = ap.parse_args(argv)
    paths = [Path(p) for p in args.paths] \
        or [Path(__file__).resolve().parents[1]]
    findings = lint_paths(paths)
    if args.as_json:
        print(json.dumps([dataclasses.asdict(f) for f in findings],
                         indent=2))
    else:
        for f in findings:
            print(f.render())
        print(f"{len(findings)} finding(s)")
    return 1 if (findings and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
