"""Serving launcher: bring up the multi-tenant OoO VLIW JIT engine.

Smoke mode runs reduced models on CPU with real token generation; the
``--mode`` flag selects the multiplexing regime so the paper's comparison
can be reproduced from the command line.

Usage (trace replay — finite trace, virtual time):
  PYTHONPATH=src python -m repro.launch.serve \
      --tenants gemma3-1b yi-9b --mode vliw --requests 8 --rate 1e4

Usage (daemon mode — the real-clock serving front door):
  PYTHONPATH=src python -m repro.launch.serve \
      --tenants gemma3-1b yi-9b --daemon --duration 5 --rate 20 \
      --admission --stats-interval 1

``--daemon`` opens a ``FrontDoor`` on the real wall clock and serves until
``--duration`` seconds have elapsed (a feeder thread submits open-loop
Poisson traffic at ``--rate``; tokens stream out per request as they
retire). ``--admission`` turns on the SLO-tiered admission controller:
each request is admitted / degraded to a lower tier / shed AT THE DOOR
from the analytic cost model + arrival forecast, and the final report
shows per-tier attainment, goodput and shed counts (shed requests count
as SLO misses). ``--stats-interval`` prints a live heartbeat line while
the daemon runs.

Note on real-clock attainment: the daemon floors the modeled device
timelines at REAL elapsed time, and on a CPU smoke host actually
executing the reduced models takes orders of magnitude longer than the
modeled TPU-v5e service times — so millisecond-scale ``--slo-ms``
deadlines will all miss and attainment reads 0%. That is the clock
semantics working, not a bug; pass a host-realistic ``--slo-ms`` (or use
the virtual-clock bench ``benchmarks/e2e_slo_attainment.py``, which
replays the door deterministically on modeled time) to study attainment.
"""
from __future__ import annotations

import argparse
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, smoke_config
from repro.models import Model
from repro.serving import (FrontDoor, ServeRequest, ServingEngine, Tenant,
                           make_trace)


def _build_models(arch_names):
    models = {}
    for i, arch in enumerate(dict.fromkeys(arch_names)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        models[arch] = (m, m.init(jax.random.PRNGKey(i + 1)))
    return models


def _make_tenants(names, archs, models, args):
    return [Tenant(n, *models[a], cache_len=max(
        32, args.prompt_len + args.max_new_tokens + 1), max_batch=4)
        for n, a in zip(names, archs)]


def _report_line(mode, rep, certify):
    line = (f"{mode:8s} modeled={rep.modeled_time_s*1e3:8.3f} ms  "
            f"mean_lat={rep.mean_latency*1e3:7.3f} ms  "
            f"p99={rep.p_latency(0.99)*1e3:7.3f} ms  "
            f"SLO={rep.slo_attainment:5.1%}  "
            f"tok/s={rep.tokens_per_s:9.0f}")
    if rep.jit:
        d = rep.jit.dispatch
        line += (f"  [superkernels={rep.jit.superkernels} "
                 f"group={rep.jit.mean_group:.2f} "
                 f"shared={rep.jit.shared_dispatches} "
                 f"wpack_hit={d.weight_hit_rate:.0%} "
                 f"retraces={d.retraces}]")
        if certify:
            line += (f"  [certified: checks={rep.jit.hazard_checks} "
                     f"violations={rep.jit.hazard_violations}]")
    return line


def _run_daemon(names, args, models) -> None:
    tenants = _make_tenants(names, args.tenants, models, args)
    eng = ServingEngine(tenants, mode="vliw", certify=args.certify,
                        num_devices=args.num_devices,
                        admission_control=args.admission)
    door = FrontDoor()

    def feeder() -> None:
        # open-loop Poisson feeder on the real clock: arrivals keep
        # coming at --rate regardless of completions, until --duration
        rng = np.random.default_rng(0)
        deadline = args.duration
        t, rid = 0.0, 0
        import time as _t
        t0 = _t.monotonic()
        while True:
            t += rng.exponential(1.0 / args.rate)
            if t >= deadline:
                break
            pause = t - (_t.monotonic() - t0)
            if pause > 0:
                _t.sleep(pause)
            tier = int(rng.choice(3, p=[0.5, 0.3, 0.2]))
            door.submit(ServeRequest(
                rid, names[rid % len(names)], 0.0, args.prompt_len,
                args.max_new_tokens, slo_s=args.slo_ms / 1e3 * (2 ** tier),
                tier=tier))
            rid += 1
        door.close()

    def heartbeat(stats) -> None:
        print(f"  [t={stats['t']:6.2f}s] submitted={stats['submitted']:4d} "
              f"finished={stats['finished']:4d} shed={stats['shed']:3d} "
              f"inflight={stats['inflight']} waiting={stats['waiting']}")

    print(f"daemon: {len(names)} tenants, {args.rate:.0f} req/s open-loop "
          f"for {args.duration:.1f}s, admission="
          f"{'on' if args.admission else 'off'}\n")
    th = threading.Thread(target=feeder, daemon=True)
    th.start()
    rep = eng.serve_forever(door, on_stats=heartbeat,
                            stats_interval_s=args.stats_interval)
    th.join()
    print()
    print(_report_line("daemon", rep, args.certify))
    print(f"  served={len(rep.requests)} shed={rep.shed} "
          f"unfinished={rep.unfinished} "
          f"goodput={rep.goodput_rps:.1f} req/s")
    for tier, att in rep.tier_attainment().items():
        n = sum(1 for r in rep.requests
                if (r.degraded_from if r.degraded_from is not None
                    else r.tier) == tier)
        print(f"  tier {tier}: attainment={att:5.1%}  n={n}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", nargs="+", default=["gemma3-1b", "yi-9b"],
                    choices=list(ARCH_IDS))
    ap.add_argument("--mode", choices=["time", "batched", "vliw", "all"],
                    default="all")
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per tenant")
    ap.add_argument("--rate", type=float, default=1e4, help="arrivals/s")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=4)
    ap.add_argument("--slo-ms", type=float, default=5.0)
    ap.add_argument("--bursty", action="store_true")
    ap.add_argument("--num-devices", type=int, default=1,
                    help="serve on an N-device modeled mesh (vliw mode): "
                         "tenants are bin-packed onto per-device timelines "
                         "at admission; expert-parallel MoE tenants span "
                         "the mesh and pay the all-to-all collective")
    ap.add_argument("--certify", action="store_true",
                    help="record a ScheduleTrace and run the hazard "
                         "certifier per tick (vliw mode); raises on the "
                         "first illegal reordering")
    ap.add_argument("--daemon", action="store_true",
                    help="real-clock front door: serve open-loop traffic "
                         "from a feeder thread until --duration elapses "
                         "(vliw mode only)")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="daemon: seconds to keep the door open")
    ap.add_argument("--admission", action="store_true",
                    help="daemon: SLO-tiered admission control at the door "
                         "(admit / degrade / shed from the cost model)")
    ap.add_argument("--stats-interval", type=float, default=1.0,
                    help="daemon: seconds between live heartbeat lines")
    args = ap.parse_args()

    models = _build_models(args.tenants)
    names = [f"t{i}:{a}" for i, a in enumerate(args.tenants)]

    if args.daemon:
        _run_daemon(names, args, models)
        return

    trace = make_trace(names, rate_hz=args.rate, n_per_tenant=args.requests,
                       prompt_len=args.prompt_len,
                       max_new_tokens=args.max_new_tokens,
                       slo_s=args.slo_ms / 1e3, bursty=args.bursty)
    print(f"{len(trace)} requests over {len(names)} tenants, "
          f"SLO {args.slo_ms} ms\n")

    modes = ["time", "batched", "vliw"] if args.mode == "all" else [args.mode]
    for mode in modes:
        tenants = _make_tenants(names, args.tenants, models, args)
        # baseline modes define single-device round semantics; the mesh is
        # a vliw-engine feature
        n_dev = args.num_devices if mode == "vliw" else 1
        eng = ServingEngine(tenants, mode=mode, certify=args.certify,
                            num_devices=n_dev)
        # run() copies the trace internally — safe to reuse across modes
        rep = eng.run(trace)
        print(_report_line(mode, rep, args.certify))
        if rep.jit and rep.num_devices > 1:
            # per-device mesh breakdown: utilization + coalesced groups
            # (from the recorded trace when --certify) + placement
            groups = {d: [0, 0] for d in range(rep.num_devices)}
            if eng.last_trace is not None:
                for rec in eng.last_trace.dispatches:
                    groups[rec.device][0] += 1
                    groups[rec.device][1] += int(len(rec.ops) > 1)
            homed = {d: [] for d in range(rep.num_devices)}
            for name, pl in eng.placement.assignments.items():
                homed[pl.device].append(
                    name + (f"(x{pl.expert_span})" if pl.expert_span > 1
                            else ""))
            print(f"  mesh: {rep.num_devices} devices, "
                  f"skew={rep.device_skew:.2f}, "
                  f"collective={rep.jit.collective_time_s*1e6:.1f} us")
            for dd in range(rep.num_devices):
                gline = (f"groups={groups[dd][0]} "
                         f"coalesced={groups[dd][1]}  "
                         if eng.last_trace is not None else "")
                print(f"    dev{dd}: util={rep.device_util[dd]:5.1%}  "
                      f"busy={rep.device_busy_s[dd]*1e3:7.3f} ms  "
                      f"{gline}tenants={','.join(homed[dd]) or '-'}")


if __name__ == "__main__":
    main()
