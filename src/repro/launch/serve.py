"""Serving launcher: bring up the multi-tenant OoO VLIW JIT engine.

Smoke mode runs reduced models on CPU with real token generation; the
``--mode`` flag selects the multiplexing regime so the paper's comparison
can be reproduced from the command line.

Usage:
  PYTHONPATH=src python -m repro.launch.serve \
      --tenants gemma3-1b yi-9b --mode vliw --requests 8 --rate 1e4
"""
from __future__ import annotations

import argparse
import copy

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, smoke_config
from repro.models import Model
from repro.serving import ServingEngine, Tenant, make_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", nargs="+", default=["gemma3-1b", "yi-9b"],
                    choices=list(ARCH_IDS))
    ap.add_argument("--mode", choices=["time", "batched", "vliw", "all"],
                    default="all")
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per tenant")
    ap.add_argument("--rate", type=float, default=1e4, help="arrivals/s")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=4)
    ap.add_argument("--slo-ms", type=float, default=5.0)
    ap.add_argument("--bursty", action="store_true")
    ap.add_argument("--num-devices", type=int, default=1,
                    help="serve on an N-device modeled mesh (vliw mode): "
                         "tenants are bin-packed onto per-device timelines "
                         "at admission; expert-parallel MoE tenants span "
                         "the mesh and pay the all-to-all collective")
    ap.add_argument("--certify", action="store_true",
                    help="record a ScheduleTrace and run the hazard "
                         "certifier per tick (vliw mode); raises on the "
                         "first illegal reordering")
    args = ap.parse_args()

    models = {}
    for i, arch in enumerate(dict.fromkeys(args.tenants)):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        models[arch] = (m, m.init(jax.random.PRNGKey(i + 1)))

    names = [f"t{i}:{a}" for i, a in enumerate(args.tenants)]
    trace = make_trace(names, rate_hz=args.rate, n_per_tenant=args.requests,
                       prompt_len=args.prompt_len,
                       max_new_tokens=args.max_new_tokens,
                       slo_s=args.slo_ms / 1e3, bursty=args.bursty)
    print(f"{len(trace)} requests over {len(names)} tenants, "
          f"SLO {args.slo_ms} ms\n")

    modes = ["time", "batched", "vliw"] if args.mode == "all" else [args.mode]
    for mode in modes:
        tenants = [Tenant(n, *models[a], cache_len=max(
            32, args.prompt_len + args.max_new_tokens + 1), max_batch=4)
            for n, a in zip(names, args.tenants)]
        # baseline modes define single-device round semantics; the mesh is
        # a vliw-engine feature
        n_dev = args.num_devices if mode == "vliw" else 1
        eng = ServingEngine(tenants, mode=mode, certify=args.certify,
                            num_devices=n_dev)
        rep = eng.run(copy.deepcopy(trace))
        line = (f"{mode:8s} modeled={rep.modeled_time_s*1e3:8.3f} ms  "
                f"mean_lat={rep.mean_latency*1e3:7.3f} ms  "
                f"p99={rep.p_latency(0.99)*1e3:7.3f} ms  "
                f"SLO={rep.slo_attainment:5.1%}  "
                f"tok/s={rep.tokens_per_s:9.0f}")
        if rep.jit:
            d = rep.jit.dispatch
            line += (f"  [superkernels={rep.jit.superkernels} "
                     f"group={rep.jit.mean_group:.2f} "
                     f"shared={rep.jit.shared_dispatches} "
                     f"wpack_hit={d.weight_hit_rate:.0%} "
                     f"retraces={d.retraces}]")
            if args.certify:
                line += (f"  [certified: checks={rep.jit.hazard_checks} "
                         f"violations={rep.jit.hazard_violations}]")
        print(line)
        if rep.jit and rep.num_devices > 1:
            # per-device mesh breakdown: utilization + coalesced groups
            # (from the recorded trace when --certify) + placement
            groups = {d: [0, 0] for d in range(rep.num_devices)}
            if eng.last_trace is not None:
                for rec in eng.last_trace.dispatches:
                    groups[rec.device][0] += 1
                    groups[rec.device][1] += int(len(rec.ops) > 1)
            homed = {d: [] for d in range(rep.num_devices)}
            for name, pl in eng.placement.assignments.items():
                homed[pl.device].append(
                    name + (f"(x{pl.expert_span})" if pl.expert_span > 1
                            else ""))
            print(f"  mesh: {rep.num_devices} devices, "
                  f"skew={rep.device_skew:.2f}, "
                  f"collective={rep.jit.collective_time_s*1e6:.1f} us")
            for dd in range(rep.num_devices):
                gline = (f"groups={groups[dd][0]} "
                         f"coalesced={groups[dd][1]}  "
                         if eng.last_trace is not None else "")
                print(f"    dev{dd}: util={rep.device_util[dd]:5.1%}  "
                      f"busy={rep.device_busy_s[dd]*1e3:7.3f} ms  "
                      f"{gline}tenants={','.join(homed[dd]) or '-'}")


if __name__ == "__main__":
    main()
