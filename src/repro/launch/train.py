"""Distributed training launcher.

Smoke mode (default, CPU-friendly): reduced config of the selected
architecture on a 1×1 host mesh, real optimization steps on the synthetic
LM pipeline, with checkpointing.

Production mode (``--production``, requires a real TPU slice or the
512-device dry-run flag): builds the 16×16 (or 2×16×16 with --multi-pod)
mesh, shards params/optimizer/batch with the rules in
distributed/sharding.py, and runs the same jitted train step under pjit.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --production \
      --multi-pod --steps 2          # on a pod slice
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.distributed.hints import activation_sharding
from repro.distributed.sharding import (batch_shardings, fsdp_axes,
                                        opt_state_shardings, param_shardings)
from repro.configs.base import InputShape
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.training import (DataConfig, OptimizerConfig, SyntheticLM,
                            init_opt_state, make_train_step, save_checkpoint)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--production", action="store_true",
                    help="full config on the production mesh (TPU slice)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    if args.production:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        dtype = jnp.bfloat16
    else:
        cfg = smoke_config(args.arch)
        mesh = make_host_mesh()
        dtype = jnp.float32
    model = Model(cfg, param_dtype=dtype, remat=args.production)
    rng = jax.random.PRNGKey(0)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps)
    data = SyntheticLM(cfg, DataConfig(batch_size=args.batch_size,
                                       seq_len=args.seq_len))
    shape = InputShape("cli", args.seq_len, args.batch_size, "train")

    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = fsdp_axes(mesh)
    bspec = dp if args.batch_size % np.prod(
        [mesh.shape[a] for a in dp]) == 0 else None
    hints = {"btd": NamedSharding(mesh, P(bspec, None, None))}
    if cfg.has_moe:
        hints["moe_groups"] = int(np.prod([mesh.shape[a] for a in dp]))
        hints["moe_tokens"] = NamedSharding(mesh, P(bspec, None, None))

    with mesh, activation_sharding(hints):
        p_sh = param_shardings(model, mesh, rng)
        params = jax.jit(model.init, out_shardings=p_sh)(rng)
        opt_sh = opt_state_shardings(p_sh, mesh)
        opt_state = jax.jit(init_opt_state, out_shardings=opt_sh)(params)
        b_sh = batch_shardings(model, shape, mesh)
        step = jax.jit(make_train_step(model, opt_cfg),
                       in_shardings=(p_sh, opt_sh, b_sh),
                       out_shardings=(p_sh, opt_sh, None),
                       donate_argnums=(0, 1))
        n_params = sum(np.prod(l.shape) for l in
                       jax.tree_util.tree_leaves(params))
        print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
              f"mesh={dict(mesh.shape)} dtype={dtype.__name__}")
        it = iter(data)
        t0 = time.perf_counter()
        for s in range(1, args.steps + 1):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt_state, metrics = step(params, opt_state, batch)
            if s % max(args.steps // 10, 1) == 0 or s == 1:
                print(f"step {s:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e}")
        wall = time.perf_counter() - t0
        print(f"{args.steps} steps in {wall:.1f}s "
              f"({wall/args.steps*1e3:.0f} ms/step host wall)")
        if args.checkpoint:
            save_checkpoint(args.checkpoint,
                            {"params": params, "opt": opt_state},
                            step=args.steps)
            print(f"checkpoint: {args.checkpoint}")


if __name__ == "__main__":
    main()
