"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports flops/bytes/collectives by ~num_layers for scan-over-layers
models (verified: a scan of 10 matmuls reports 1 matmul of flops). This
module parses the optimized HLO text instead:

  * builds the computation call graph (while bodies/conditions, fusions,
    calls, conditionals);
  * reads each while's ``known_trip_count`` from backend_config;
  * counts dot flops exactly (result elements × 2 × contraction size),
    fusion-aware HBM traffic (fusion operands/results only), and collective
    operand bytes;
  * rolls everything up through the call graph with trip multipliers.

Shapes in the per-device SPMD module are per-device, so all results are
per-chip quantities — exactly what the roofline formulas need.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3b11fnuz": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CALLED_RE = re.compile(
    r"(?:condition|body|calls|to_apply|true_computation|false_computation)"
    r"=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# opcodes that move no real data (bookkeeping; control-flow ops pass
# references — their bodies' real traffic is counted inside the called
# computations)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "custom-call", "conditional", "call"}


def _shape_list_bytes(text: str) -> int:
    return sum(_shape_elems(d, dims) * _DTYPE_BYTES.get(d, 0)
               for d, dims in _SHAPE_RE.findall(text))


def _shape_elems(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for x in dims.split(","):
            n *= int(x)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_elems: int
    result_shape_str: str
    operands: List[str]
    attrs: str
    paren: str = ""      # raw text inside the opcode's parentheses


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]          # instr name -> result type text


def _parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = everything before the opcode token
        om = re.match(r"((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)"
                      r")\s+([\w\-]+)\(", rhs)
        if not om:
            continue
        rtype, opcode = om.group(1), om.group(2)
        paren_start = rhs.find("(", om.start(2))
        depth, i = 0, paren_start
        while i < len(rhs):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        operand_text = rhs[paren_start + 1:i]
        attrs = rhs[i + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_text)
        elems = sum(_shape_elems(d, s) for d, s in _SHAPE_RE.findall(rtype))
        cur.instrs.append(Instr(name, opcode, _shape_list_bytes(rtype),
                                elems, rtype, operands, attrs,
                                paren=operand_text))
        cur.shapes[name] = rtype
    return comps


def _dot_flops(instr: Instr, comp: Computation) -> float:
    """2 × result_elems × contraction_size (batch dims are in the result)."""
    if not instr.operands:
        return 0.0
    lhs_type = comp.shapes.get(instr.operands[0], "")
    mm = _SHAPE_RE.search(lhs_type)
    if not mm:
        return 0.0
    lhs_dims = [int(x) for x in mm.group(2).split(",")] if mm.group(2) else []
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                   instr.attrs + " ".join([]))
    # contracting dims may appear in the operand tail (attrs holds them)
    if not cm:
        return 0.0
    csize = 1
    for d in cm.group(1).split(","):
        if d:
            csize *= lhs_dims[int(d)]
    return 2.0 * instr.result_elems * csize


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})

    def scaled(self, m: float) -> "CostTotals":
        return CostTotals(self.flops * m, self.bytes * m,
                          self.collective_bytes * m,
                          {k: v * m for k, v in self.per_collective.items()})

    def add(self, o: "CostTotals") -> None:
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.per_collective.items():
            self.per_collective[k] += v


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps = _parse_computations(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: Dict[Tuple[str, bool], CostTotals] = {}

    def _find_entry(self, hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        return m.group(1) if m else next(iter(self.comps))

    # ------------------------------------------------------------------
    def analyze(self) -> CostTotals:
        return self._comp_cost(self.entry, in_fusion=False)

    def _instr_io_bytes(self, ins: Instr, comp: Computation) -> float:
        """Physical HBM traffic of one instruction.

        In-place and sparse-access ops must NOT be charged their full buffer:
        a dynamic-update-slice inside a scan writes only the slice (XLA
        aliases the buffer), dynamic-slice/gather read only what they
        produce. Charging full buffers inflated scan-heavy models ~300×
        (measured on mamba2 prefill: h_prev [512,2,80,64,128] charged once
        per inner×outer loop step = 88 TB of phantom traffic).
        """
        if ins.opcode in ("dynamic-slice", "gather"):
            return 2.0 * ins.result_bytes
        if ins.opcode == "dynamic-update-slice":
            upd = _shape_list_bytes(comp.shapes.get(ins.operands[1], "")) \
                if len(ins.operands) > 1 else ins.result_bytes
            return 2.0 * upd
        if ins.opcode == "fusion":
            return self._fusion_io_bytes(ins, comp)
        return ins.result_bytes + sum(
            _shape_list_bytes(comp.shapes.get(o, ""))
            for o in ins.operands)

    def _fusion_io_bytes(self, ins: Instr, comp: Computation) -> float:
        """Fusion traffic with sliced-access awareness.

        Scan bodies consume loop ``xs`` through FUSED dynamic-slices and
        write carries through fused dynamic-update-slices: charging the full
        array per iteration inflates scan-heavy models by the trip count
        (measured 80+ TB of phantom reads on mamba2's inter-chunk scan). A
        fusion parameter consumed only by dynamic-slice/gather is charged
        those ops' RESULT sizes; a dynamic-update-slice root is charged the
        update size.
        """
        fc = None
        for c in _CALLED_RE.findall(ins.attrs):
            fc = self.comps.get(c)
            if fc is not None:
                break
        if fc is None:
            return ins.result_bytes + sum(
                _shape_list_bytes(comp.shapes.get(o, ""))
                for o in ins.operands)
        # map parameter index -> name, and find each parameter's consumers
        param_names: Dict[int, str] = {}
        for fi in fc.instrs:
            if fi.opcode == "parameter":
                m = re.match(r"\s*(\d+)\s*$", fi.paren)
                idx = int(m.group(1)) if m else len(param_names)
                param_names[idx] = fi.name
        total = 0.0
        for i, op_name in enumerate(ins.operands):
            full = _shape_list_bytes(comp.shapes.get(op_name, ""))
            pname = param_names.get(i)
            if pname is None:
                total += full
                continue
            consumers = [fi for fi in fc.instrs if pname in fi.operands]
            if consumers and all(fi.opcode in ("dynamic-slice", "gather")
                                 for fi in consumers):
                total += sum(fi.result_bytes for fi in consumers)
            elif consumers and all(
                    fi.opcode == "dynamic-update-slice"
                    and fi.operands and fi.operands[0] == pname
                    for fi in consumers):
                # in-place carry buffer: reads nothing beyond the update
                pass
            else:
                total += full
        root = fc.instrs[-1] if fc.instrs else None
        if root is not None and root.opcode == "dynamic-update-slice" \
                and len(root.operands) > 1:
            total += _shape_list_bytes(fc.shapes.get(root.operands[1], ""))
        else:
            total += ins.result_bytes
        return total

    def _fusion_root(self, ins: Instr):
        called = _CALLED_RE.findall(ins.attrs)
        for c in called:
            comp = self.comps.get(c)
            if comp and comp.instrs:
                root = comp.instrs[-1]
                return (root.opcode, root, comp)
        return None

    def _comp_cost(self, name: str, in_fusion: bool) -> CostTotals:
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = CostTotals()
        if comp is None:
            self._memo[key] = total
            return total
        for ins in comp.instrs:
            # ---- flops (counted even inside fusions) ----------------------
            if ins.opcode == "dot":
                total.flops += _dot_flops(ins, comp)
            # ---- collectives ----------------------------------------------
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") \
                else ins.opcode
            if base in COLLECTIVE_OPS:
                b = sum(_shape_list_bytes(comp.shapes.get(o, ""))
                        for o in ins.operands)
                total.collective_bytes += b
                total.per_collective[base] += b
            # ---- memory traffic (only at non-fused level) -----------------
            if not in_fusion and ins.opcode not in _FREE_OPS:
                total.bytes += self._instr_io_bytes(ins, comp)
            # ---- recurse into called computations -------------------------
            called = _CALLED_RE.findall(ins.attrs)
            bm = _BRANCHES_RE.search(ins.attrs)
            if bm:
                called += re.findall(r"%?([\w.\-]+)", bm.group(1))
            if not called:
                continue
            trip = 1
            if ins.opcode == "while":
                tm = _TRIP_RE.search(ins.attrs)
                trip = int(tm.group(1)) if tm else 1
            child_fusion = in_fusion or ins.opcode == "fusion"
            children = list(dict.fromkeys(called))
            if ins.opcode == "conditional" and len(children) > 1:
                # one branch executes per invocation; absent runtime branch
                # statistics, charge the MEAN across branches (documented in
                # EXPERIMENTS.md — e.g. a 5:1 local:global attention cond
                # truly runs the cheap branch 5/6 of the time).
                trip = trip / len(children)
            for c in children:
                sub = self._comp_cost(c, child_fusion)
                total.add(sub.scaled(trip))
        self._memo[key] = total
        return total


def analyze_hlo(hlo_text: str) -> CostTotals:
    return HloAnalyzer(hlo_text).analyze()
