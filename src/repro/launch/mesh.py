"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests must keep seeing a single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
