"""Post-compile HLO analysis: collective-traffic accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes but not collective traffic, so
we parse the optimized HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(including their -start async forms).

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# a shape literal like bf16[256,1024]{1,0} or f32[] or (tuple, ...)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes summed over the module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            # opcode appears right after the result shape
            if re.search(rf"\b{c}(-start)?\(", rhs):
                kind = c
                break
        if kind is None:
            continue
        # operand shapes are inside the call parens; result shape precedes it
        paren = rhs.find("(")
        operands = rhs[paren + 1:]
        shapes = _SHAPE_RE.findall(operands)
        if not shapes:  # fall back to the result shape
            shapes = _SHAPE_RE.findall(rhs[:paren])
        out[kind] += sum(_shape_bytes(d, s) for d, s in shapes)
    return out


@dataclasses.dataclass
class RooflineTerms:
    """Per-step execution-time lower bounds (seconds), whole-slice."""
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    chips: int
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline(hlo_flops: float, hlo_bytes: float, coll_bytes: float,
             chips: int, model_flops: float = 0.0) -> RooflineTerms:
    """Assignment formulas. cost_analysis() reports per-device numbers under
    SPMD, so flops/bytes are per-chip already; collective bytes are from the
    per-device HLO module as well."""
    return RooflineTerms(
        compute_s=hlo_flops / PEAK_FLOPS,
        memory_s=hlo_bytes / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, coll_bytes=coll_bytes,
        chips=chips, model_flops=model_flops)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference forward),
    N = active params (MoE: top-k), D = tokens processed in the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1  # decode: one token per sequence
    return 2.0 * n * d
