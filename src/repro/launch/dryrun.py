import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) with ShapeDtypeStruct stand-ins (no allocation).

The two lines above MUST run before any jax import — jax locks the device
count at first init. Do not set that flag globally; smoke tests and benches
must see one device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full sweep
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json (resumable).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, \
    pair_is_supported
from repro.distributed.hints import activation_sharding
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        fsdp_axes, opt_state_shardings,
                                        param_shardings)
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import model_flops_for, roofline
from repro.launch.hlo_parse import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _result_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    suffix = f"__{tag}" if tag else ""
    return os.path.abspath(os.path.join(
        OUT_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json"))


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh); return the roofline record."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = Model(cfg, param_dtype=jnp.bfloat16, remat=(shape.kind == "train"))
    rng = jax.random.PRNGKey(0)

    dp = fsdp_axes(mesh)
    bspec = dp if shape.global_batch % (
        2 * 16 if multi_pod else 16) == 0 else None
    hints = {"btd": NamedSharding(mesh, P(bspec, None, None))}
    if cfg.has_moe:
        # GShard grouped dispatch (§Perf G2): one token group per data shard
        hints["moe_groups"] = 32 if multi_pod else 16
        hints["moe_tokens"] = NamedSharding(mesh, P(dp, None, None))
        if cfg.moe.num_experts % (2 * 16 if multi_pod else 16) != 0:
            # grok-style MoE (not expert-parallel): force ZeRO-3 weight
            # gathering instead of activation all-reduce (§Perf G1); buffer
            # stays group-local.
            hints["moe_w_col"] = NamedSharding(mesh, P(None, None, "model"))
            hints["moe_w_row"] = NamedSharding(mesh, P(None, "model", None))
            hints["moe_buf"] = NamedSharding(mesh, P(dp, None, None, None))

    t0 = time.perf_counter()
    with mesh, activation_sharding(hints):
        p_sh = param_shardings(model, mesh, rng)
        p_shape = jax.eval_shape(model.init, rng)
        in_specs = model.input_specs(shape)
        b_sh = batch_shardings(model, shape, mesh)

        if shape.kind == "train":
            opt_sh = opt_state_shardings(p_sh, mesh)
            opt_shape = jax.eval_shape(init_opt_state, p_shape)
            step = make_train_step(model, OptimizerConfig())
            jitted = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                             out_shardings=(p_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_shape, opt_shape, in_specs)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                return model.prefill(params, batch, cache_len=shape.seq_len)

            jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_shape, in_specs)
        else:  # decode
            cache_shape = in_specs["cache"]
            c_sh = cache_shardings(model, cache_shape, mesh, shape)
            tok_sh = b_sh["tokens"] if "tokens" in b_sh else None

            def serve_step(params, tokens, cache):
                return model.decode_step(params, tokens, cache)

            jitted = jax.jit(serve_step,
                             in_shardings=(p_sh, tok_sh, c_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(p_shape, in_specs["tokens"], cache_shape)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # trip-count-aware HLO accounting (XLA's cost_analysis counts while
    # bodies once — see launch/hlo_parse.py); all quantities are per-chip.
    totals = analyze_hlo(hlo)
    coll = {k: v for k, v in totals.per_collective.items() if v}
    flops = totals.flops
    bytes_ = totals.bytes
    mf = model_flops_for(cfg, shape) / chips  # per-chip useful flops
    terms = roofline(flops, bytes_, totals.collective_bytes, chips,
                     model_flops=mf)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single", "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "collectives": coll,
        "roofline": terms.as_dict(),
        "xla_cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
    }
    if verbose:
        print(f"[{arch} × {shape_name} × "
              f"{'multi' if multi_pod else 'single'}] "
              f"compile={t_compile:.1f}s flops/chip={flops:.3e} "
              f"bytes/chip={bytes_:.3e} coll={sum(coll.values()):.3e}B "
              f"dominant={terms.dominant}")
        print(f"  memory_analysis: args={record['memory']['argument_bytes']} "
              f"temp={record['memory']['temp_bytes']} "
              f"peak={record['memory']['peak_bytes']}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="sweep every supported (arch, shape, mesh)")
    ap.add_argument("--force", action="store_true",
                    help="recompute existing results")
    ap.add_argument("--tag", default="", help="variant tag for perf runs")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    if args.all:
        combos = [(a, s, m)
                  for a in ARCH_IDS
                  for s in INPUT_SHAPES
                  for m in ("single", "multi")
                  if pair_is_supported(a, s)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        combos = [(args.arch, args.shape, m) for m in meshes]

    failures = []
    for arch, shape, mesh_name in combos:
        path = _result_path(arch, shape, mesh_name, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"skip (cached): {os.path.basename(path)}")
            continue
        try:
            rec = dryrun_one(arch, shape, mesh_name == "multi")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:  # noqa: BLE001 — record and continue sweep
            print(f"FAIL {arch} {shape} {mesh_name}: {e}")
            traceback.print_exc()
            failures.append((arch, shape, mesh_name, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
