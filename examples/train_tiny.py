"""Train a ~1M-param reduced gemma3-family model for a few hundred steps on
the synthetic LM pipeline, with checkpointing — demonstrating the training
substrate (optimizer, data, checkpoint) end-to-end on CPU.

Run:  PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import dataclasses
import os

import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import Model
from repro.training import (DataConfig, OptimizerConfig, SyntheticLM,
                            checkpoint_step, train)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, num_layers=2)
    model = Model(cfg, param_dtype=jnp.float32)
    n_params = cfg.param_count()
    print(f"arch family: {args.arch} (reduced) — {n_params/1e6:.2f}M params")

    data = SyntheticLM(cfg, DataConfig(batch_size=8, seq_len=128, seed=0))
    ckpt = os.path.join("experiments", "train_tiny.npz")
    res = train(model, data, steps=args.steps,
                opt_cfg=OptimizerConfig(lr=1e-3, warmup_steps=20,
                                        total_steps=args.steps),
                log_every=20, checkpoint_path=ckpt,
                checkpoint_every=max(args.steps // 2, 1))
    first = sum(res["losses"][:10]) / 10
    last = sum(res["losses"][-10:]) / 10
    print(f"\nloss: {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({res['wall_s']:.0f}s wall)")
    print(f"checkpoint at step {checkpoint_step(ckpt)}: {ckpt}")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
