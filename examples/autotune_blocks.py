"""Paper Table 1 live: AOT-autotune a kernel for sole tenancy (greedy) vs
co-tenancy (collaborative), then verify the collaborative tile choice on the
REAL Pallas superkernel in interpret mode.

Run:  PYTHONPATH=src python examples/autotune_blocks.py
"""
import jax
import jax.numpy as jnp

from repro.core import Autotuner, CostModel, GemmShape, V100
from repro.kernels.ops import execute_superkernel


def main() -> None:
    cm = CostModel(V100)
    at = Autotuner(cm)
    shape = GemmShape(m=784, n=512, k=1152, dtype_bytes=4)
    print(f"problem: GEMM {shape.m}x{shape.k} @ {shape.k}x{shape.n} "
          f"(conv-like, fp32)\n")
    for K in (2, 4):
        r = at.tune(shape, co_tenants=K)
        print(f"co-tenants={K}")
        print(f"  greedy block        {r.greedy}   isolated "
              f"{cm.achieved_tflops([shape], r.greedy_isolated_s):.2f} TF")
        print(f"  collaborative block {r.collaborative}   isolated "
              f"{cm.achieved_tflops([shape], r.collab_isolated_s):.2f} TF")
        print(f"  multiplexed: greedy "
              f"{cm.achieved_tflops([shape]*K, r.greedy_multiplexed_s):.2f} "
              f"TF vs collaborative "
              f"{cm.achieved_tflops([shape]*K, r.collab_multiplexed_s):.2f} "
              f"TF -> {r.multiplexed_speedup:.2f}x (paper: 1.25x)\n")

    # run the collaborative configuration on the real Pallas superkernel
    r = at.tune(shape, co_tenants=2)
    b = r.collaborative
    rng = jax.random.PRNGKey(0)
    probs = []
    for i in range(2):
        ka, kb = jax.random.split(jax.random.fold_in(rng, i))
        probs.append((jax.random.normal(ka, (196, 288), jnp.float32),
                      jax.random.normal(kb, (288, 128), jnp.float32)))
    outs = execute_superkernel(probs, bm=min(b.bm, 64), bn=128,
                               bk=min(b.bk, 96))
    err = max(float(jnp.max(jnp.abs(o - a @ bm))) for (a, bm), o
              in zip(probs, outs))
    print(f"collaborative tile on real grouped-GEMM kernel "
          f"(reduced size, interpret mode): max err {err:.1e}")


if __name__ == "__main__":
    main()
