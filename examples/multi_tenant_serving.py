"""End-to-end driver: serve a small model zoo with batched requests.

Three tenants (dense gemma3-family, dense yi-family, attention-free mamba2)
receive Poisson request traffic with latency SLOs; the engine runs the same
trace under all three multiplexing regimes and prints the paper's comparison
(§4 vs §5) with REAL greedy token generation.

Run:  PYTHONPATH=src python examples/multi_tenant_serving.py
"""

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.scheduler import SchedulerConfig
from repro.models import Model
from repro.serving import ServingEngine, Tenant, make_trace, two_wave_trace


def main() -> None:
    def mk(arch, seed):
        cfg = smoke_config(arch)
        m = Model(cfg, param_dtype=jnp.float32)
        return m, m.init(jax.random.PRNGKey(seed))

    m1, p1 = mk("gemma3-1b", 1)
    m2, p2 = mk("yi-9b", 2)
    m3, p3 = mk("mamba2-2.7b", 3)

    trace = make_trace(["chat", "code", "summarize"], rate_hz=2e4,
                       n_per_tenant=4, prompt_len=8, max_new_tokens=6,
                       slo_s=0.005, bursty=True)
    print(f"trace: {len(trace)} requests over 3 tenants "
          f"(bursty Poisson, 5 ms SLO)\n")

    results = {}
    for mode in ("time", "batched", "vliw"):
        tenants = [Tenant("chat", m1, p1, cache_len=32, max_batch=4),
                   Tenant("code", m2, p2, cache_len=32, max_batch=4),
                   Tenant("summarize", m3, p3, cache_len=32, max_batch=4)]
        eng = ServingEngine(tenants, mode=mode)
        rep = eng.run(trace)
        results[mode] = rep
        line = (f"{mode:8s} modeled={rep.modeled_time_s*1e3:7.3f} ms  "
                f"mean_lat={rep.mean_latency*1e3:7.3f} ms  "
                f"p99={rep.p_latency(0.99)*1e3:7.3f} ms  "
                f"SLO={rep.slo_attainment:5.1%}  "
                f"tok/s={rep.tokens_per_s:9.0f}")
        if rep.jit:
            line += (f"  [superkernels={rep.jit.superkernels} "
                     f"mean_group={rep.jit.mean_group:.2f} "
                     f"waits={rep.jit.waits} "
                     f"mid_flight={rep.jit.mid_flight_admissions} "
                     f"evictions={rep.jit.evictions} "
                     f"wpack_hit={rep.jit.dispatch.weight_hit_rate:.0%}]")
        print(line)

    a = [r.tokens_out for r in sorted(results["time"].requests,
                                      key=lambda r: r.req_id)]
    b = [r.tokens_out for r in sorted(results["vliw"].requests,
                                      key=lambda r: r.req_id)]
    print(f"\ngreedy tokens identical across regimes: {a == b}")
    speedup = results["time"].modeled_time_s / results["vliw"].modeled_time_s
    print(f"VLIW JIT speedup over time-multiplexing: {speedup:.2f}x")

    # --- the paper's §5.2 stagger, live: a second wave arrives just after
    # the first; an arrival-aware scheduler WAITs to coalesce with it -----
    print("\nstaged two-wave arrivals (WAIT vs never-wait):")
    probe = ServingEngine([Tenant("w1", m1, p1, cache_len=32, max_batch=2)],
                          mode="vliw")
    gap = 1.2 * probe._prefill_time(m1.cfg, 8)
    staged = two_wave_trace(["w1"], ["w2"], gap, prompt_len=8,
                            max_new_tokens=6, slo_s=1.0)
    for label, sc in (("wait", SchedulerConfig(min_wait_gain_s=0.0,
                                               max_wait_s=0.05)),
                      ("never-wait", SchedulerConfig(max_wait_s=0.0))):
        eng = ServingEngine([Tenant("w1", m1, p1, cache_len=32, max_batch=2),
                             Tenant("w2", m1, p1, cache_len=32, max_batch=2)],
                            mode="vliw", sched_cfg=sc)
        rep = eng.run(staged)
        print(f"  {label:10s} waits={rep.jit.waits:2d} "
              f"mean_group={rep.jit.mean_group:.2f} "
              f"superkernels={rep.jit.superkernels} "
              f"modeled={rep.modeled_time_s*1e6:6.1f} us")


if __name__ == "__main__":
    main()
