"""Quickstart: the OoO VLIW JIT in 60 seconds.

Builds two small tenant models, declares their decode steps to the JIT, and
shows the paper's three mechanisms working: shape clustering, superkernel
coalescing (real Pallas grouped-GEMM execution), and SLO-aware accounting.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core import CostModel, GemmShape, TPUV5E, V100, cluster_greedy, \
    zoo_population
from repro.core.jit import VLIWJit, build_dense_decode_program
from repro.models import Model


def main() -> None:
    rng = jax.random.PRNGKey(0)

    # --- 1. Fig-7 moment: the model zoo's GEMMs cluster tightly ------------
    from repro.configs import REGISTRY
    shapes = [s for _, _, s in zoo_population(list(REGISTRY.values()))]
    clusters = cluster_greedy(shapes)
    print(f"zoo: {len(shapes)} GEMM problems -> {len(clusters)} clusters "
          f"(<=25% padding waste each)")

    # --- 2. build two tenants and prefill them -----------------------------
    tenants = []
    for arch, seed in (("gemma3-1b", 1), ("yi-9b", 2)):
        cfg = smoke_config(arch)
        model = Model(cfg, param_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(seed))
        prompt = {"tokens": jax.random.randint(rng, (2, 12), 0,
                                               cfg.vocab_size)}
        logits, cache = model.prefill(params, prompt, cache_len=32)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
        tenants.append((model, params, tok.astype(jnp.int32), cache))
        print(f"tenant {arch}: prefilled 12 tokens, first decode token "
              f"{tok[:, 0].tolist()}")

    # --- 3. declare both decode steps to the JIT and run coalesced ---------
    jit = VLIWJit(CostModel(TPUV5E), max_group=8)
    progs = [build_dense_decode_program(m, p, t, c, stream_id=i)
             for i, (m, p, t, c) in enumerate(tenants)]
    stats = jit.run(progs)
    print(f"\nVLIW JIT: {stats.ops_executed} declared GEMMs -> "
          f"{stats.superkernels} superkernels "
          f"(mean group {stats.mean_group:.2f}, "
          f"{stats.shared_dispatches} shared-weight dispatches)")
    print(f"modeled speedup vs time-multiplexed dispatch: "
          f"{stats.modeled_speedup:.2f}x")
    for i, (model, params, tok, cache) in enumerate(tenants):
        ref, _ = model.decode_step(params, tok, cache)
        err = float(jnp.max(jnp.abs(progs[i].env["logits"][:, None] - ref)))
        print(f"tenant {i}: JIT output matches monolithic decode "
              f"(max err {err:.1e})")


if __name__ == "__main__":
    main()
